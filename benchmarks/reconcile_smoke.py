"""Fail-loudly planner reconciliation smoke — CI gate for the registry.

    PYTHONPATH=src python -m benchmarks.reconcile_smoke

Runs ``obs.reconcile.run`` over EVERY ``StrategyProbe`` registry strategy
(dr, dd, pd, pd_xt, pd_xyt, dd_lpt, hybrid) on an 8-device CPU mesh
(2x2x2 pod/data/model fake hosts) with ``reps=1`` on a tiny domain, then
exits non-zero if any registry strategy or any timing term is missing
from the emitted rows. CI runs this as its own leg so a probe that
silently stops building (e.g. a registry entry whose builder signature
drifted) fails the build instead of quietly thinning the dashboards.
"""
import os
import sys

# must be set before jax is imported anywhere
os.environ.setdefault("JAX_PLATFORMS", "cpu")
_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        _flags + " --xla_force_host_platform_device_count=8").strip()

_root = os.path.join(os.path.dirname(__file__), "..")
sys.path.insert(0, _root)
sys.path.insert(0, os.path.join(_root, "src"))


def main() -> int:
    import jax

    from repro.core import Domain, clustered_events
    from repro.obs import reconcile

    dom = Domain(gx=48.0, gy=48.0, gt=16.0, sres=1.0, tres=1.0,
                 hs=3.0, ht=2.0)
    pts = clustered_events(1500, dom, seed=0)
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    res = reconcile.run(pts, dom, mesh, reps=1)
    print(res["report"])

    missing = []
    for strat in reconcile.PROBED:
        have = {r["term"] for r in res["rows"] if r["strategy"] == strat}
        missing += [f"{strat}/{t}" for t in reconcile.TERMS if t not in have]
    if missing:
        print("MISSING reconcile rows:", ", ".join(missing))
        return 1
    print(f"reconcile smoke ok: {len(reconcile.PROBED)} strategies x "
          f"{len(reconcile.TERMS)} terms = {len(res['rows'])} rows "
          f"on mesh {res['mesh']}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
