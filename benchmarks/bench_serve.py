"""Serving benchmark: continuous batching (slot-swap) vs the bucketed
reference on a mixed-length workload.

Both engines serve the *same* workload — 32 requests (12 with --quick),
prompt lengths drawn from a small set between 8 and 128 so jit caches
amortize, varied ``max_new`` — after one untimed warmup pass per engine.
Reported per mode: tokens/s (wall clock, swaps included), decode-only
tokens/s, mean/p95 queue wait, mean slot idle fraction
(1 - active_slot_steps / slot_steps), and whether greedy outputs are
token-identical across the two schedulers (they must be).
"""
import numpy as np


def _workload(cfg, quick: bool):
    n = 12 if quick else 32
    lens = (8, 12, 16, 24, 32) if quick else (8, 16, 32, 48, 64, 96, 128)
    hi = 12 if quick else 32
    rng = np.random.default_rng(0)
    wl = []
    for uid in range(n):
        L = int(rng.choice(lens))
        wl.append((uid, rng.integers(0, cfg.vocab, L).astype(np.int32),
                   int(rng.integers(4, hi + 1))))
    return wl


def _serve(eng, wl):
    for uid, prompt, max_new in wl:
        eng.submit(uid, prompt, max_new=max_new)
    return eng.run()


def run(quick: bool = False):
    import jax

    from repro.configs import ARCHS, reduced
    from repro.models import init_params
    from repro.serve import EngineConfig, ServingEngine

    cfg = reduced(ARCHS["smollm-360m"])
    params = init_params(cfg, jax.random.PRNGKey(0))
    wl = _workload(cfg, quick)
    max_seq = max(len(p) for _, p, _ in wl) + max(m for _, _, m in wl) + 1

    rows, tokens = [], {}
    for mode, cont in (("bucketed", False), ("continuous", True)):
        eng = ServingEngine(cfg, params, EngineConfig(
            max_batch=4, max_seq=max_seq, continuous_batching=cont))
        _serve(eng, wl)                      # warmup: pays jit compiles
        tokens[mode] = _serve(eng, wl)       # timed pass
        st = eng.last_stats
        qw = st["queue_wait_s"] or [0.0]
        rows.append({
            "bench": f"serve_{mode}",
            "n_requests": len(wl),
            "wall_s": st["wall_s"],
            "n_tokens": st["n_tokens"],
            "tokens_per_s": st["n_tokens"] / st["wall_s"]
            if st["wall_s"] else 0.0,
            "decode_tokens_per_s": st["n_tokens"] / st["decode_s"]
            if st["decode_s"] else 0.0,
            "mean_queue_wait_s": float(np.mean(qw)),
            "p95_queue_wait_s": float(np.percentile(qw, 95)),
            "slot_idle_frac": 1.0 - st["active_slot_steps"]
            / st["slot_steps"] if st["slot_steps"] else 0.0,
            "swaps": st["swaps"],
        })

    identical = (
        set(tokens["bucketed"]) == set(tokens["continuous"])
        and all(tokens["bucketed"][u].tolist()
                == tokens["continuous"][u].tolist()
                for u in tokens["bucketed"])
    )
    for r in rows:
        r["identical_greedy"] = identical

    for r in rows:
        print(f"  {r['bench']:<18} {r['n_tokens']:>5} tok  "
              f"{r['tokens_per_s']:>8.1f} tok/s  "
              f"idle {r['slot_idle_frac']:.3f}  "
              f"p95 wait {r['p95_queue_wait_s'] * 1e3:.1f} ms")
    bkt, con = rows
    print(f"  greedy identical across schedulers: {identical}")
    if bkt["slot_idle_frac"] > 0:
        print(f"  slot idle reduction: {bkt['slot_idle_frac']:.3f} -> "
              f"{con['slot_idle_frac']:.3f}")
    return rows
