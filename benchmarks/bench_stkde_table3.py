"""Table 3 reproduction: sequential algorithm comparison.

VB / VB-DEC / PB / PB-DISK / PB-BAR / PB-SYM on scaled-down instances of
every paper dataset (grids shrunk to CPU scale, bandwidths preserved so the
per-point cylinder work — the quantity the algorithms differ on — is
unchanged). Reports runtime and the PB-SYM-over-PB speedup column; the
paper's claims to check: PB ≫ VB (orders of magnitude), PB-SYM speedup
1x–7x growing with bandwidth, VB-DEC between VB and PB.
"""
from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Domain, vb, vb_dec, pb, bench_suite
from repro.core.pb import pb_eval_only, _pb_eval_impl
from repro.core import kernels_math as km
from repro.obs import timeit


def _eval_flops(pts_shape, dom, variant) -> float:
    """Compiled FLOPs of the kernel-evaluation phase (per point block;
    XLA counts the streaming while-loop body once — ratios are exact)."""
    f = jax.jit(lambda p: _pb_eval_impl(
        p, dom, variant, km.DEFAULT_KS, km.DEFAULT_KT, 1 << 22))
    co = f.lower(jax.ShapeDtypeStruct(pts_shape, jnp.float32)).compile()
    ca = co.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):       # older jax: one dict per device
        ca = ca[0] if ca else {}
    return float(ca.get("flops", 0.0))

# instances small enough that VB itself is measurable on CPU
VB_INSTANCES = ["Dengue_Lr-Lb", "Dengue_Lr-Hb", "PollenUS_Lr-Lb",
                "Flu_Lr-Lb", "Flu_Lr-Hb"]
# instances for the point-based family (VB too slow; matches paper's blanks)
PB_INSTANCES = VB_INSTANCES + [
    "Dengue_Hr-Lb", "Dengue_Hr-Hb", "PollenUS_Hr-Lb", "PollenUS_Hr-Mb",
    "Flu_Mr-Lb", "Flu_Mr-Hb", "eBird_Lr-Lb",
]


def _time(fn, *args, reps=3, name=None, **kw) -> float:
    return timeit(lambda: fn(*args, **kw), reps=reps, name=name).best


def run(max_voxels=400_000, max_points=6_000, quick=False) -> List[Dict]:
    suite = bench_suite(max_voxels=max_voxels, max_points=max_points)
    rows = []
    names = PB_INSTANCES[:4] if quick else PB_INSTANCES
    for name in names:
        inst = suite[name]
        dom = inst.domain()
        pts = inst.points()
        row = {"instance": name, "n": inst.n,
               "grid": f"{dom.Gx}x{dom.Gy}x{dom.Gt}",
               "Hs": dom.Hs, "Ht": dom.Ht}
        jpts = jnp.asarray(pts)
        if name in VB_INSTANCES and not quick:
            row["vb_s"] = round(
                _time(vb, jpts, dom, reps=1, name="table3.vb"), 4)
            row["vb_dec_s"] = round(
                _time(vb_dec, pts, dom, reps=1, name="table3.vb_dec"), 4)
        for variant, col in (("pb", "pb_s"), ("disk", "pb_disk_s"),
                             ("bar", "pb_bar_s"), ("sym", "pb_sym_s")):
            row[col] = round(
                _time(lambda: pb(pts, dom, variant=variant),
                      name=f"table3.{col[:-2]}"), 4
            )
            # compute phase only (paper Fig. 7 phase split: on vectorized
            # XLA the scatter/accumulate phase is variant-independent and
            # dominates on CPU; Table 3's algorithmic story lives in the
            # kernel-evaluation phase)
            row[col[:-2] + "_eval_s"] = round(
                _time(lambda: pb_eval_only(pts, dom, variant=variant),
                      name=f"table3.{col[:-2]}_eval"), 4
            )
        row["sym_speedup"] = round(row["pb_s"] / max(row["pb_sym_s"], 1e-9),
                                   3)
        row["sym_eval_speedup"] = round(
            row["pb_eval_s"] / max(row["pb_sym_eval_s"], 1e-9), 3)
        # the paper's Table-3 claim at the algorithmic (flop) level:
        fl = {v: _eval_flops(pts.shape, dom, v)
              for v in ("pb", "disk", "bar", "sym")}
        row["flops_pb"] = fl["pb"]
        row["flops_sym"] = fl["sym"]
        row["sym_flop_speedup"] = round(fl["pb"] / max(fl["sym"], 1.0), 3)
        row["disk_flop_speedup"] = round(fl["pb"] / max(fl["disk"], 1.0), 3)
        row["bar_flop_speedup"] = round(fl["pb"] / max(fl["bar"], 1.0), 3)
        if "vb_s" in row:
            row["vb_over_pbsym"] = round(
                row["vb_s"] / max(row["pb_sym_s"], 1e-9), 1
            )
        rows.append(row)
        print(f"  {name}: pb={row['pb_s']}s sym={row['pb_sym_s']}s "
              f"wall-speedup={row['sym_speedup']}x "
              f"flop-speedup={row['sym_flop_speedup']}x")
    return rows
