"""Parallel strategy comparison (paper Figures 8-15 analogues).

Two parts:
  (a) multi-device speedups of DR / DD / PD / DD-LPT / hybrid on 8 fake host
      devices (subprocess — the main process keeps 1 device), including the
      clustered-load case where LPT placement matters (Fig. 13 story), and
      the DD overhead sweep (Fig. 9 story: decomposition multiplies work).
  (b) the coloring/critical-path study (Fig. 12): naive 8-coloring vs
      load-aware coloring T_inf on real instance point distributions, plus
      list-schedule simulated speedups (Graham bound check).
"""
from __future__ import annotations

import json
import os
import pathlib
import subprocess
import sys
from typing import Dict, List

import numpy as np

from repro.core import bench_suite, bucketing, coloring
from repro.distributed import partition
from repro.obs import metrics as obs_metrics, trace as obs_trace

SRC = str(pathlib.Path(__file__).resolve().parent.parent / "src")

# All subprocess timing goes through repro.obs.timeit (one warmup +
# block_until_ready code path); spans and metrics are exported through the
# RESULT json and merged into the parent's tracer/registry.
_SUBPROC = r"""
import json
import repro.compat
import numpy as np, jax
from repro.core import pb, bench_suite
from repro.distributed.stkde_dist import STRATEGIES
from repro.obs import metrics, timeit, trace

suite = bench_suite(max_voxels=500_000, max_points=8_000)
inst = suite[{name!r}]
dom = inst.domain()
pts = inst.points()

seq = timeit(lambda: pb(pts, dom), name="parallel.seq_pb_sym",
             instance={name!r}).best
rows = {{"instance": {name!r}, "seq_pb_sym_s": seq}}
mesh = jax.make_mesh((4, 2), ("data", "model"))
want = np.asarray(pb(pts, dom))
for strat in ("dr", "dd", "pd", "dd_lpt"):
    fn = STRATEGIES[strat]
    try:
        t = timeit(lambda: fn(pts, dom, mesh), name="parallel." + strat,
                   instance={name!r}).best
        got = np.asarray(fn(pts, dom, mesh))
        ok = bool(np.abs(got - want).max() < 1e-5)
        rows[strat + "_s"] = t
        rows[strat + "_speedup"] = seq / t
        rows[strat + "_correct"] = ok
    except ValueError as e:
        rows[strat + "_s"] = None
        rows[strat + "_note"] = str(e)[:60]
rows["_trace_events"] = trace.get_tracer().export_events()
rows["_metrics"] = metrics.export()
print("RESULT" + json.dumps(rows))
"""

_RECONCILE_SUBPROC = r"""
import json
import repro.compat
import jax
from repro.core import bench_suite
from repro.obs import metrics, reconcile, trace

suite = bench_suite(max_voxels=500_000, max_points=8_000)
inst = suite[{name!r}]
dom = inst.domain()
pts = inst.points()
# 3-axis mesh: pod serves as hybrid's rep axis / pd_xyt's X cut; the
# worker-2D strategies span (data, model) and leave pod replicated
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
out = reconcile.run(pts, dom, mesh, reps={reps})
out["instance"] = {name!r}
out["_trace_events"] = trace.get_tracer().export_events()
out["_metrics"] = metrics.export()
print("RESULT" + json.dumps(out))
"""

_CHAOS_SUBPROC = r"""
import json
import repro.compat
import numpy as np, jax
from repro.core import pb, bench_suite
from repro.core.api import stkde
from repro.resilience import faults
from repro.obs import metrics, timeit, trace

suite = bench_suite(max_voxels=500_000, max_points=8_000)
inst = suite[{name!r}]
dom = inst.domain()
pts = inst.points()
mesh = jax.make_mesh((4, 2), ("data", "model"))
want = np.asarray(pb(pts, dom))
reps = {reps}
clean = timeit(lambda: stkde(pts, dom, mesh=mesh, strategy="pd"),
               reps=reps, name="chaos.clean", instance={name!r}).mean
faults.configure({spec!r}, seed={seed})
chaos = timeit(lambda: stkde(pts, dom, mesh=mesh, strategy="pd"),
               reps=reps, name="chaos.injected", instance={name!r}).mean
got = np.asarray(stkde(pts, dom, mesh=mesh, strategy="pd"))
ok = bool(np.abs(got - want).max() < 1e-5)
c = metrics.export()["counters"]
rows = {{"instance": {name!r}, "bench": "chaos", "spec": {spec!r},
        "clean_s": clean, "chaos_s": chaos,
        "recovery_overhead_pct":
            100.0 * (chaos - clean) / clean if clean else None,
        "correct": ok,
        "injected": c.get("resilience.injected", 0),
        "retries": c.get("resilience.retries", 0),
        "fallbacks": c.get("resilience.fallbacks", 0),
        "gave_up": c.get("resilience.gave_up", 0)}}
rows["_trace_events"] = trace.get_tracer().export_events()
rows["_metrics"] = metrics.export()
print("RESULT" + json.dumps(rows))
"""

_CHUNKED_SUBPROC = r"""
import json, os, tempfile
import repro.compat
import numpy as np, jax
from repro.core import get_instance, pb
from repro.core.api import stkde_chunked
from repro.data.pipeline import stkde_stream
from repro.obs import metrics, timeit, trace

inst = get_instance({name!r}).scaled(max_voxels=300_000, max_points={n})
dom = inst.domain()
chunk = {chunk}
mesh = jax.make_mesh((4, 2), ("data", "model"))

# reference: the same points in one monolithic shot (the path the old
# bench_suite 8k-point cap protected); the stream is deterministic, so a
# second pass re-draws the identical point set
all_pts = np.concatenate([c for c, _ in stkde_stream(inst, chunk=chunk)])
mono = timeit(lambda: pb(all_pts, dom), reps={reps},
              name="chunked.mono", instance=inst.name).mean
want = np.asarray(pb(all_pts, dom))

jdir = tempfile.mkdtemp()
def run_once():
    return stkde_chunked(stkde_stream(inst, chunk=chunk), dom, mesh=mesh,
                         strategy="dr", journal=os.path.join(jdir, "j"))
res = run_once()
chunked = timeit(run_once, reps={reps}, name="chunked.run",
                 instance=inst.name).mean
ok = bool(np.abs(res.grid - want).max() < 1e-5)
rows = {{"instance": inst.name, "bench": "chunked", "n": int(inst.n),
        "chunk_size": chunk, "chunks": res.report["chunks_total"],
        "max_chunk_points": res.report["max_chunk_points"],
        "mono_s": mono, "chunked_s": chunked,
        "chunked_overhead_pct":
            100.0 * (chunked - mono) / mono if mono else None,
        "coverage": res.report["coverage"], "correct": ok}}
rows["_trace_events"] = trace.get_tracer().export_events()
rows["_metrics"] = metrics.export()
print("RESULT" + json.dumps(rows))
"""

_sub_pid = 0   # synthetic pid per subprocess for the merged Chrome trace


def _run_sub(code: str, n_dev: int = 8) -> dict:
    global _sub_pid
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    # benchmarks measure; chaos is opt-in per section (run_chaos passes
    # its spec explicitly), so the ambient injection env must not leak
    # into direct-strategy timing subprocesses
    env.pop("REPRO_FAULTS", None)
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=1200)
    if proc.returncode != 0:
        raise RuntimeError(proc.stderr[-2000:])
    for line in proc.stdout.splitlines():
        if line.startswith("RESULT"):
            r = json.loads(line[len("RESULT"):])
            _sub_pid += 1
            events = r.pop("_trace_events", None)
            if events:
                obs_trace.get_tracer().ingest(events, pid=_sub_pid)
            exported = r.pop("_metrics", None)
            if exported:
                obs_metrics.get_registry().merge(exported)
            return r
    raise RuntimeError("no RESULT line:\n" + proc.stdout[-2000:])


def run_reconcile(instance="Flu_Mr-Hb", quick=False) -> List[Dict]:
    """Planner predicted-vs-measured phase reconciliation (8-device mesh).

    Probes every strategy in the ``obs.reconcile.PROBED`` registry on a
    2x2x2 pod/data/model mesh in the same 8-fake-device subprocess as
    the speedup benchmarks; needs an instance whose 2x2 worker subdomains
    satisfy every strategy's bandwidth constraint (subdomain >= Hs/Ht).
    """
    r = _run_sub(_RECONCILE_SUBPROC.format(
        name=instance, reps=2 if quick else 3))
    print(r["report"])
    return [r]


DEFAULT_CHAOS_SPEC = ("dist.halo:nan:0.2,ckpt.write:corrupt:0.2,"
                      "data.read:drop:0.1")


def run_chaos(instance="Flu_Mr-Hb", spec=DEFAULT_CHAOS_SPEC, seed=42,
              quick=False) -> List[Dict]:
    """Chaos benchmark: the traced api-level query under fault injection.

    Times the same PD query clean and with ``spec`` injection enabled
    (retry + fallback-to-dr absorb the faults), reporting the recovery
    overhead — the number ``make_report.py`` surfaces as the price of
    resilience.
    """
    r = _run_sub(_CHAOS_SUBPROC.format(
        name=instance, spec=spec, seed=seed, reps=3 if quick else 5))
    print(f"  {instance}: clean={r['clean_s']:.3f}s "
          f"chaos={r['chaos_s']:.3f}s "
          f"(+{r['recovery_overhead_pct']:.1f}% recovery overhead; "
          f"{r['injected']:.0f} injected, {r['fallbacks']:.0f} fallbacks, "
          f"correct={r['correct']})")
    return [r]


def run_chunked(instance="Flu_Mr-Hb", quick=False) -> List[Dict]:
    """Chunked-vs-monolithic benchmark at 32k points (4x the bench_suite
    point cap): bounded-memory streamed ingestion + progress journaling
    on the 8-device mesh, priced against the one-shot baseline.
    """
    n = 16_000 if quick else 32_000
    r = _run_sub(_CHUNKED_SUBPROC.format(
        name=instance, n=n, chunk=4096, reps=1 if quick else 2))
    print(f"  {r['instance']}: n={r['n']} in {r['chunks']} chunks "
          f"(max {r['max_chunk_points']} pts buffered), "
          f"mono={r['mono_s']:.3f}s chunked={r['chunked_s']:.3f}s "
          f"(+{r['chunked_overhead_pct']:.1f}%), correct={r['correct']}")
    return [r]


def run_speedups(instances=("Dengue_Lr-Hb", "PollenUS_Lr-Lb", "Flu_Mr-Hb"),
                 quick=False) -> List[Dict]:
    rows = []
    for name in (instances[:1] if quick else instances):
        r = _run_sub(_SUBPROC.format(name=name))
        rows.append(r)
        msg = ", ".join(
            f"{s}={r.get(s + '_speedup'):.2f}x"
            for s in ("dr", "dd", "pd", "dd_lpt")
            if r.get(s + "_speedup") is not None
        )
        print(f"  {name}: seq={r['seq_pb_sym_s']:.3f}s  {msg}")
    return rows


def run_dd_overhead(name="PollenUS_Hr-Mb", decomps=(1, 2, 4, 8, 16)) -> List[
        Dict]:
    """Fig. 9: replication factor (= work overhead) vs decomposition size."""
    suite = bench_suite(max_voxels=500_000, max_points=8_000)
    inst = suite[name]
    dom = inst.domain()
    pts = inst.points()
    rows = []
    for d in decomps:
        tile = (max(1, -(-dom.Gx // d)), max(1, -(-dom.Gy // d)), dom.Gt)
        b = bucketing.bucket_points_overlap(pts, dom, tile)
        rows.append({
            "instance": name, "decomp": f"{d}x{d}x1",
            "replication_factor": round(b.replication_factor, 3),
        })
        print(f"  {name} {d}x{d}: replication "
              f"{b.replication_factor:.3f}x")
    return rows


def run_coloring_study(instances=("Dengue_Lr-Hb", "PollenUS_Hr-Mb",
                                  "Flu_Mr-Hb"),
                       decomp=(16, 16, 4), P=16) -> List[Dict]:
    """Fig. 12/13: T_inf naive vs load-aware; simulated speedups; LPT."""
    suite = bench_suite(max_voxels=500_000, max_points=8_000)
    rows = []
    for name in instances:
        inst = suite[name]
        dom = inst.domain()
        pts = inst.points()
        tile = (max(1, -(-dom.Gx // decomp[0])),
                max(1, -(-dom.Gy // decomp[1])),
                max(1, -(-dom.Gt // decomp[2])))
        b = bucketing.bucket_points_home(pts, dom, tile)
        loads = b.counts.reshape(-1).astype(float)
        shape = b.ntiles
        T1 = loads.sum()
        naive = coloring.naive_coloring(shape)
        smart = coloring.load_aware_coloring(shape, loads)
        tinf_naive = coloring.critical_path(shape, naive, loads)
        tinf_smart = coloring.critical_path(shape, smart, loads)
        sim_naive = coloring.simulate_schedule(shape, naive, loads, P)
        sim_smart = coloring.simulate_schedule(shape, smart, loads, P)
        eff, rep = coloring.replicate_critical(shape, smart, loads, P)
        tinf_rep = coloring.critical_path(shape, smart, eff)
        lpt = partition.imbalance_stats(loads, P)
        rows.append({
            "instance": name,
            "tinf_naive_pct": round(100 * tinf_naive / T1, 2),
            "tinf_sched_pct": round(100 * tinf_smart / T1, 2),
            "tinf_rep_pct": round(100 * tinf_rep / T1, 2),
            "sim_speedup_naive": round(T1 / sim_naive, 2),
            "sim_speedup_sched": round(T1 / sim_smart, 2),
            "graham_bound_sched": round(
                T1 / coloring.graham_bound(T1, tinf_smart, P), 2),
            "lpt_imbalance": round(lpt["lpt_imbalance"], 3),
            "block_imbalance": round(lpt["block_imbalance"], 3),
            "replicated_tasks": int((rep > 1).sum()),
        })
        print(f"  {name}: T_inf {rows[-1]['tinf_naive_pct']}% -> "
              f"{rows[-1]['tinf_sched_pct']}% (sched) -> "
              f"{rows[-1]['tinf_rep_pct']}% (rep); sim speedup "
              f"{rows[-1]['sim_speedup_naive']} -> "
              f"{rows[-1]['sim_speedup_sched']}")
    return rows
