"""Benchmark harness entry point — one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only SECTION]

Sections:
  table3     sequential algorithms (paper Table 3)
  parallel   multi-device strategy speedups (Figs. 8/10/11/13/15)
  ddover     DD decomposition overhead (Fig. 9)
  coloring   critical path / scheduling study (Fig. 12)
  kernel     Pallas tile-kernel structural benchmark
  roofline   roofline table from dry-run artifacts (§Roofline)
  serve      continuous-batching vs bucketed serving engine
  chunked    crash-safe chunked execution at 32k points (journal overhead)

Output: ``name,us_per_call,derived`` CSV lines to stdout + JSON to
results/bench/.

With ``--trace``, also writes results/bench/trace.json (Chrome trace —
load in chrome://tracing or Perfetto) and metrics.json, and the parallel
section additionally runs the planner predicted-vs-measured phase
reconciliation (-> reconcile.json + a printed report).

With ``--chaos``, additionally runs the fault-injection benchmark
(``REPRO_FAULTS`` spec override honored): the traced api-level STKDE
query timed clean vs under injection, reporting recovery overhead
(retry/backoff + fallback-to-dr); ``make_report.py`` renders the
resilience section from these rows + metrics.json.
"""
import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

SECTIONS = ("table3", "parallel", "ddover", "coloring", "kernel",
            "roofline", "serve", "chunked")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", nargs="*", default=list(SECTIONS),
                    choices=SECTIONS)
    ap.add_argument("--out", default="results/bench")
    ap.add_argument("--trace", action="store_true",
                    help="export Chrome trace + metrics + reconciliation")
    ap.add_argument("--chaos", action="store_true",
                    help="add the fault-injection benchmark (recovery "
                         "overhead; REPRO_FAULTS overrides the spec)")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    all_results = {}

    if "table3" in args.only:
        print("== table3: sequential algorithm comparison ==")
        from benchmarks import bench_stkde_table3
        all_results["table3"] = bench_stkde_table3.run(quick=args.quick)
    if "parallel" in args.only:
        print("== parallel: strategy speedups (8 devices) ==")
        from benchmarks import bench_stkde_parallel
        all_results["parallel"] = bench_stkde_parallel.run_speedups(
            quick=args.quick)
        if args.trace:
            print("== parallel: planner reconciliation (8 devices) ==")
            all_results["reconcile"] = bench_stkde_parallel.run_reconcile(
                quick=args.quick)
            with open(os.path.join(args.out, "reconcile.json"), "w") as f:
                json.dump(all_results["reconcile"], f, indent=1,
                          default=float)
    if "ddover" in args.only:
        print("== ddover: DD replication overhead (Fig 9) ==")
        from benchmarks import bench_stkde_parallel
        all_results["ddover"] = bench_stkde_parallel.run_dd_overhead()
    if "coloring" in args.only:
        print("== coloring: critical path & scheduling (Fig 12) ==")
        from benchmarks import bench_stkde_parallel
        all_results["coloring"] = bench_stkde_parallel.run_coloring_study()
    if "kernel" in args.only:
        print("== kernel: Pallas tile structure ==")
        from benchmarks import bench_kernel
        all_results["kernel"] = bench_kernel.run(quick=args.quick)
    if "roofline" in args.only:
        print("== roofline: dry-run derived table ==")
        from benchmarks import bench_roofline
        if os.path.isdir("results/dryrun/single"):
            all_results["roofline"] = bench_roofline.run()
        else:
            print("  (no dry-run artifacts; run repro.launch.dryrun first)")
    if "serve" in args.only:
        print("== serve: continuous vs bucketed engine ==")
        from benchmarks import bench_serve
        all_results["serve"] = bench_serve.run(quick=args.quick)
    if "chunked" in args.only:
        print("== chunked: crash-safe chunked STKDE at 32k points ==")
        from benchmarks import bench_stkde_parallel
        all_results["chunked"] = bench_stkde_parallel.run_chunked(
            quick=args.quick)

    if args.chaos:
        print("== chaos: fault-injection recovery overhead (8 devices) ==")
        from benchmarks import bench_stkde_parallel
        spec = os.environ.get(
            "REPRO_FAULTS", bench_stkde_parallel.DEFAULT_CHAOS_SPEC)
        seed = int(os.environ.get("REPRO_FAULTS_SEED", "42"))
        all_results["chaos"] = bench_stkde_parallel.run_chaos(
            spec=spec, seed=seed, quick=args.quick)

    with open(os.path.join(args.out, "results.json"), "w") as f:
        json.dump(all_results, f, indent=1, default=float)

    if args.trace:
        from repro.obs import metrics as obs_metrics, trace as obs_trace
        tpath = os.path.join(args.out, "trace.json")
        obs_trace.save_chrome_trace(tpath)
        obs_metrics.save_json(os.path.join(args.out, "metrics.json"))
        n_ev = len(obs_trace.get_tracer().to_chrome_trace()["traceEvents"])
        print(f"\n[obs] {n_ev} events -> {tpath} (chrome://tracing), "
              f"metrics -> {args.out}/metrics.json")

    # required CSV summary: name,us_per_call,derived
    print("\nname,us_per_call,derived")
    for section, rows in all_results.items():
        for r in rows:
            name = r.get("instance") or r.get("cell") or r.get("bench") or \
                r.get("decomp", "?")
            t = None
            for k in ("pb_sym_s", "seq_pb_sym_s", "scatter_pb_s"):
                if r.get(k) is not None:
                    t = r[k] * 1e6
                    break
            derived = (r.get("sym_speedup") or r.get("dr_speedup")
                       or r.get("bottleneck") or r.get("mxu_fill")
                       or r.get("replication_factor")
                       or r.get("tinf_sched_pct")
                       or r.get("recovery_overhead_pct")
                       or r.get("chunked_overhead_pct")
                       or r.get("tokens_per_s") or "")
            print(f"{section}:{name},{'' if t is None else round(t, 1)},"
                  f"{derived}")


if __name__ == "__main__":
    main()
