"""Generate EXPERIMENTS.md §Dry-run and §Roofline tables from artifacts.

    PYTHONPATH=src python -m benchmarks.make_report > results/report.md
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List


def load(outdir="results/dryrun_final") -> Dict[str, List[dict]]:
    out = {}
    for mesh in ("single", "multi"):
        rows = []
        for p in sorted(glob.glob(os.path.join(outdir, mesh, "*.json"))):
            with open(p) as f:
                rows.append(json.load(f))
        out[mesh] = rows
    return out


def _fmt_bytes(b):
    if b is None:
        return "-"
    return f"{b / 2**30:.2f}"


def _is_stkde(r):
    return r["arch"].startswith("stkde-")


def dryrun_table(rows: List[dict]) -> str:
    lines = [
        "| cell | status | compile s | HBM/dev GiB | fits 16G | "
        "coll/dev GiB | coll ops |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        cell = f"{r['arch']} × {r['shape']}"
        if r.get("skipped"):
            lines.append(f"| {cell} | SKIP (sub-quadratic-only shape) "
                         f"| - | - | - | - | - |")
            continue
        if not r.get("ok"):
            lines.append(f"| {cell} | **FAIL** {r.get('error', '')[:60]} "
                         f"| - | - | - | - | - |")
            continue
        mem = r["memory"]
        per_dev = mem["argument_size_in_bytes"] + mem.get(
            "temp_per_device", mem["temp_size_in_bytes"] // r["chips"])
        coll = r.get("collectives", {})
        lines.append(
            f"| {cell} | OK | {r.get('compile_s', '-')} | "
            f"{_fmt_bytes(per_dev)} | {'Y' if r.get('fits_hbm') else 'N'} | "
            f"{_fmt_bytes(coll.get('total'))} | {coll.get('n_ops', '-')} |"
        )
    return "\n".join(lines)


def roofline_table(rows: List[dict]) -> str:
    lines = [
        "| cell | compute ms | memory ms | collective ms | bottleneck | "
        "useful/algo flops | MFU bound | one-line lever |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r.get("skipped") or not r.get("ok") or "roofline" not in r:
            continue
        rf = r["roofline"]
        lever = suggest_lever(r)
        lines.append(
            f"| {r['arch']} × {r['shape']} | {rf['compute_s']*1e3:.2f} | "
            f"{rf['memory_s']*1e3:.2f} | {rf['collective_s']*1e3:.2f} | "
            f"**{rf['bottleneck']}** | {rf['useful_flops_ratio']:.2f} | "
            f"{rf['mfu_bound']:.3f} | {lever} |"
        )
    return "\n".join(lines)


def suggest_lever(r: dict) -> str:
    rf = r["roofline"]
    b = rf["bottleneck"]
    if _is_stkde(r):
        return {"collective": "shrink halo / psum extent",
                "memory": "fuse init with first accumulation pass",
                "compute": "raise tile GEMM arithmetic intensity",
                }[b]
    if b == "collective":
        if r["shape"].startswith("train"):
            if "moe" in r["arch"] or r["arch"].startswith(
                    ("dbrx", "deepseek")):
                return "explicit all-to-all MoE dispatch (shard_map)"
            return "overlap grad all-reduce/param gathers with compute"
        return "keep decode cache movement in-shard (flash-decoding)"
    if b == "memory":
        if r["shape"].startswith("decode") or r["shape"] == "long_500k":
            return "bf16/int8 weights + paged KV to cut per-step HBM reads"
        return "recompute less (selective remat) / fuse optimizer update"
    return "increase per-chip batch or sequence to amortize"


def reconcile_table(results: List[dict]) -> str:
    """Markdown table of planner predicted-vs-measured phase times
    (results/bench/reconcile.json, written by ``run.py --trace``)."""
    lines = [
        "| instance | strategy | term | predicted s | measured s | "
        "rel err |",
        "|---|---|---|---|---|---|",
    ]
    for res in results:
        inst = res.get("instance", "?")
        for r in res.get("rows", []):
            lines.append(
                f"| {inst} | {r['strategy']} | {r['term']} | "
                f"{r['predicted_s']:.3e} | {r['measured_s']:.3e} | "
                f"{r['rel_err']:+.1%} |"
            )
    return "\n".join(lines)


def resilience_table(chaos_rows: List[dict], metrics: dict) -> str:
    """Markdown resilience section: chaos-benchmark recovery overhead
    (results/bench/results.json "chaos" rows, from ``run.py --chaos``)
    plus the resilience.* counters from metrics.json."""
    lines = [
        "| instance | spec | clean s | chaos s | recovery overhead | "
        "injected | retries | fallbacks | correct |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in chaos_rows:
        lines.append(
            f"| {r.get('instance', '?')} | `{r.get('spec', '')}` | "
            f"{r['clean_s']:.3f} | {r['chaos_s']:.3f} | "
            f"{r['recovery_overhead_pct']:+.1f}% | "
            f"{r.get('injected', 0):.0f} | {r.get('retries', 0):.0f} | "
            f"{r.get('fallbacks', 0):.0f} | "
            f"{'Y' if r.get('correct') else 'N'} |"
        )
    counters = {
        k: v for k, v in metrics.get("counters", {}).items()
        if k.startswith("resilience.")
    }
    if counters:
        lines.append("")
        lines.append("| resilience counter | value |")
        lines.append("|---|---|")
        for k in sorted(counters):
            lines.append(f"| `{k}` | {counters[k]:.0f} |")
    back = metrics.get("histograms", {}).get("resilience.backoff_s")
    if back:
        lines.append(
            f"\nBackoff time: {back['count']:.0f} sleeps, "
            f"{back['sum']*1e3:.1f} ms total "
            f"(p95 {back['p95']*1e3:.2f} ms) — the injected-fault "
            "recovery budget."
        )
    return "\n".join(lines)


def serving_table(serve_rows: List[dict]) -> str:
    """Markdown serving section: continuous-batching vs bucketed engine
    (results/bench/results.json "serve" rows, from ``run.py --only
    serve``)."""
    lines = [
        "| engine | req | tokens | tok/s (wall) | tok/s (decode) | "
        "slot idle | mean wait ms | p95 wait ms | greedy == oracle |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    by_bench = {}
    for r in serve_rows:
        by_bench[r.get("bench")] = r
        lines.append(
            f"| {r.get('bench', '?')} | {r.get('n_requests', 0)} | "
            f"{r.get('n_tokens', 0)} | {r.get('tokens_per_s', 0):.1f} | "
            f"{r.get('decode_tokens_per_s', 0):.1f} | "
            f"{r.get('slot_idle_frac', 0):.3f} | "
            f"{r.get('mean_queue_wait_s', 0) * 1e3:.1f} | "
            f"{r.get('p95_queue_wait_s', 0) * 1e3:.1f} | "
            f"{'Y' if r.get('identical_greedy') else 'N'} |"
        )
    bkt = by_bench.get("serve_bucketed")
    con = by_bench.get("serve_continuous")
    if bkt and con:
        lines.append(
            f"\nSlot idle fraction {bkt['slot_idle_frac']:.3f} → "
            f"{con['slot_idle_frac']:.3f}; slot-swap reclaims the decode "
            "steps the bucketed engine burns on finished rows "
            "(docs/serving.md)."
        )
    return "\n".join(lines)


def chunked_table(rows: List[dict]) -> str:
    """Markdown chunked-execution section (results.json "chunked" rows,
    from ``run.py --only chunked``)."""
    lines = [
        "| instance | n | chunks | max pts buffered | mono s | chunked s | "
        "journal overhead | correct |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        lines.append(
            f"| {r.get('instance', '?')} | {r.get('n', 0)} | "
            f"{r.get('chunks', 0)} | {r.get('max_chunk_points', 0)} | "
            f"{r.get('mono_s', 0):.3f} | {r.get('chunked_s', 0):.3f} | "
            f"{r.get('chunked_overhead_pct', 0):+.1f}% | "
            f"{'Y' if r.get('correct') else 'N'} |"
        )
    return "\n".join(lines)


def check_chaos_section(results: dict) -> List[dict]:
    """The chaos recovery-overhead rows are an acceptance artifact
    (mirroring ``check_serve_section``): if benchmark results exist but
    carry no chaos data, fail loudly instead of silently emitting a
    report without the resilience section."""
    chaos_rows = results.get("chaos", [])
    bad = [r for r in chaos_rows
           if r.get("recovery_overhead_pct") is None
           or "correct" not in r]
    if not chaos_rows or bad:
        raise SystemExit(
            "make_report: resilience section has no chaos data"
            + (f" (malformed rows: {len(bad)})" if bad else "")
            + " — run `PYTHONPATH=src python -m benchmarks.run --chaos` "
            "(any section selection works, e.g. `--only serve --chaos`) "
            "first"
        )
    return chaos_rows


def check_serve_section(results: dict) -> List[dict]:
    """The bucketed-vs-continuous comparison is an acceptance artifact:
    if the benchmark results exist but the serve section is missing or
    one-sided, fail loudly instead of silently emitting a report without
    it."""
    serve_rows = results.get("serve", [])
    benches = {r.get("bench") for r in serve_rows}
    missing = {"serve_bucketed", "serve_continuous"} - benches
    if missing:
        raise SystemExit(
            "make_report: serving comparison has no data for "
            f"{sorted(missing)} — run `PYTHONPATH=src python -m "
            "benchmarks.run --only serve` (or a full run) first"
        )
    return serve_rows


def summarize(rows):
    ok = sum(1 for r in rows if r.get("ok") and not r.get("skipped"))
    skip = sum(1 for r in rows if r.get("skipped"))
    fail = sum(1 for r in rows if not r.get("ok"))
    return ok, skip, fail


def main():
    data = load()
    for mesh in ("single", "multi"):
        rows = data[mesh]
        ok, skip, fail = summarize(rows)
        chips = 256 if mesh == "single" else 512
        print(f"\n### Dry-run — {mesh} pod mesh "
              f"({'16x16' if mesh == 'single' else '2x16x16'}, {chips} "
              f"chips): {ok} OK / {skip} skip / {fail} fail\n")
        print(dryrun_table(rows))
    print("\n### Roofline — single pod (per assignment)\n")
    lm = [r for r in data["single"] if not _is_stkde(r)]
    st = [r for r in data["single"] if _is_stkde(r)]
    print(roofline_table(lm))
    print("\n### Roofline — STKDE production-scale cells\n")
    print(roofline_table(st))
    rec = "results/bench/reconcile.json"
    if os.path.exists(rec):
        with open(rec) as f:
            results = json.load(f)
        mesh_s = results[0].get("mesh", "?") if results else "?"
        print(f"\n### Planner reconciliation — predicted vs measured "
              f"(host mesh {mesh_s})\n")
        print(reconcile_table(results))
        print("\nHost compute predictions use the calibrated `plan.HOST` "
              "constants (two-rate fit via `plan.calibrate_host`: "
              "scatter-path strategies share one flops rate, `dd_lpt`'s "
              "GEMM tile path is priced via `mxu_derate`); compute "
              "rel-err across all seven registry strategies should sit "
              "inside the 5x acceptance band. Residual comm-term error "
              "is expected — collectives measure ~0 on shared memory.")
    res_p = "results/bench/results.json"
    met_p = "results/bench/metrics.json"
    chaos_rows = []
    met = {}
    bench_results = None
    if os.path.exists(res_p):
        with open(res_p) as f:
            bench_results = json.load(f)
        chaos_rows = check_chaos_section(bench_results)
    if os.path.exists(met_p):
        with open(met_p) as f:
            met = json.load(f)
    if chaos_rows or any(k.startswith("resilience.")
                         for k in met.get("counters", {})):
        print("\n### Resilience — chaos benchmark "
              "(`run.py --chaos`, docs/resilience.md)\n")
        print(resilience_table(chaos_rows, met))
    if bench_results is not None:
        serve_rows = check_serve_section(bench_results)
        print("\n### Serving — continuous batching vs bucketed "
              "(`run.py --only serve`, docs/serving.md)\n")
        print(serving_table(serve_rows))
        if bench_results.get("chunked"):
            print("\n### Chunked execution — crash-safe streaming at 32k "
                  "points (`run.py --only chunked`, docs/resilience.md)\n")
            print(chunked_table(bench_results["chunked"]))


if __name__ == "__main__":
    main()
