"""Roofline table from the dry-run JSON artifacts (results/dryrun)."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List


def load_cells(outdir: str = "results/dryrun_final",
               mesh: str = "single") -> List[Dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(outdir, mesh, "*.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def run(outdir: str = "results/dryrun_final", quick: bool = False) -> List[Dict]:
    rows = []
    for cell in load_cells(outdir):
        if cell.get("skipped"):
            rows.append({"cell": f"{cell['arch']}:{cell['shape']}",
                         "status": "SKIP"})
            continue
        if not cell.get("ok"):
            rows.append({"cell": f"{cell['arch']}:{cell['shape']}",
                         "status": "FAIL"})
            continue
        r = cell.get("roofline", {})
        rows.append({
            "cell": f"{cell['arch']}:{cell['shape']}",
            "status": "OK",
            "compute_ms": round(r.get("compute_s", 0) * 1e3, 3),
            "memory_ms": round(r.get("memory_s", 0) * 1e3, 3),
            "collective_ms": round(r.get("collective_s", 0) * 1e3, 3),
            "bottleneck": r.get("bottleneck", "?"),
            "mfu_bound": round(r.get("mfu_bound", 0), 4),
            "fits_hbm": cell.get("fits_hbm"),
        })
    for row in rows:
        print("  " + json.dumps(row))
    return rows
