"""Pallas tile-kernel microbenchmark: scatter-PB vs tile oracle vs the
kernel's structural cost model.

On CPU the Pallas kernel runs in interpret mode (not a wall-clock signal);
what we benchmark here is (a) the *scatter* path vs the *dense tile* path in
XLA:CPU — the structural advantage that motivates the TPU kernel — and (b)
the kernel's analytic MXU utilisation per tile configuration (the numbers
that justify the default_tile choice in kernels/ops.py).
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.core import Domain, pb, clustered_events, bucketing
from repro.kernels import stkde_tiled, default_tile
from repro.obs import timeit


def tile_gemm_stats(dom: Domain, tile, cap: int) -> Dict:
    """Structural analysis of one tile GEMM (V_s x P) @ (P x V_t)."""
    bx, by, bt = tile
    m, k, n = bx * by, cap, bt
    flops = 2 * m * k * n
    # bytes: Ks panel + Kt panel + accumulator (VMEM-resident)
    vmem = 4 * (k * m + k * n + m * n)
    # MXU alignment: fraction of 128x128 systolic tiles that are full
    util_m = m / (-(-m // 128) * 128)
    util_n = n / (-(-n // 128) * 128)
    return {
        "tile": f"{bx}x{by}x{bt}", "gemm": f"({m}x{k})@({k}x{n})",
        "flops_per_tile": flops, "vmem_bytes": vmem,
        "mxu_fill": round(util_m * util_n, 3),
        "arith_intensity": round(flops / vmem, 1),
    }


def run(quick=False) -> List[Dict]:
    dom = Domain(gx=96.0, gy=96.0, gt=32.0, sres=1.0, tres=1.0,
                 hs=4.0, ht=2.0)
    pts = clustered_events(3000 if quick else 10_000, dom, seed=0)
    rows = []
    t_scatter = timeit(lambda: pb(pts, dom), name="kernel.scatter_pb").best
    t_tiled_ref = timeit(lambda: stkde_tiled(pts, dom, use_ref=True),
                         name="kernel.tiled_dense").best
    rows.append({
        "bench": "scatter_vs_tiled(cpu)",
        "scatter_pb_s": round(t_scatter, 4),
        "tiled_dense_s": round(t_tiled_ref, 4),
        "note": "dense tile path = structure the TPU kernel exploits",
    })
    print(f"  scatter={t_scatter:.4f}s tiled(dense jnp)={t_tiled_ref:.4f}s")
    for tile, cap in (((8, 8, 8), 128), ((16, 16, 8), 256),
                      ((32, 32, 16), 512), ((32, 32, 8), 1024)):
        s = tile_gemm_stats(dom, tile, cap)
        rows.append({"bench": "tile_gemm_structure", **s})
        print(f"  tile {s['tile']}: {s['gemm']} MXU fill {s['mxu_fill']} "
              f"AI {s['arith_intensity']}")
    return rows
