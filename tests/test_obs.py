"""Observability layer: spans, Chrome export, metrics, timer, reconcile."""
import json

import numpy as np
import pytest

from repro.obs import metrics, timeit, trace
from util_subproc import run_with_devices


# ------------------------------------------------------------------ trace
def test_span_nesting_and_attrs():
    tr = trace.Tracer()
    with tr.span("outer", a=1) as outer:
        with tr.span("inner") as inner:
            inner.set(found=3)
    spans = {s.name: s for s in tr.spans()}
    assert set(spans) == {"outer", "inner"}
    assert spans["inner"].parent_id == spans["outer"].span_id
    assert spans["outer"].parent_id is None
    assert spans["outer"].attrs == {"a": 1}
    assert spans["inner"].attrs == {"found": 3}
    # inner closed first and fits inside outer
    assert spans["inner"].duration_ns <= spans["outer"].duration_ns
    assert spans["inner"].start_ns >= spans["outer"].start_ns


def test_global_span_helper_records():
    with trace.span("unit.test", k="v") as sp:
        pass
    assert sp.duration_s >= 0
    assert trace.get_tracer().spans("unit.test")


def test_chrome_trace_schema(tmp_path):
    tr = trace.Tracer()
    with tr.span("phase", n=7, arr=np.arange(2)):
        pass
    doc = tr.to_chrome_trace()
    # must round-trip through json (numpy attrs coerced to strings)
    doc2 = json.loads(json.dumps(doc))
    assert doc2["displayTimeUnit"] == "ms"
    (ev,) = doc2["traceEvents"]
    assert ev["ph"] == "X"
    assert ev["name"] == "phase"
    for key in ("ts", "dur", "pid", "tid", "args"):
        assert key in ev
    assert ev["dur"] >= 0
    assert ev["args"]["n"] == 7
    p = tmp_path / "trace.json"
    tr.save(str(p))
    assert json.loads(p.read_text())["traceEvents"]


def test_ingest_foreign_events():
    tr = trace.Tracer()
    tr.ingest([{"name": "child", "ph": "X", "ts": 1.0, "dur": 2.0,
                "pid": 0, "tid": 0, "args": {}}], pid=42)
    evs = tr.to_chrome_trace()["traceEvents"]
    assert evs[0]["pid"] == 42


# ---------------------------------------------------------------- metrics
def test_counter_and_gauge():
    metrics.counter("t.c").inc()
    metrics.counter("t.c").inc(2)
    metrics.gauge("t.g").set(1.5)
    d = metrics.export()
    assert d["counters"]["t.c"] == 3
    assert d["gauges"]["t.g"] == 1.5


def test_histogram_percentiles():
    h = metrics.Histogram()
    for v in range(1, 101):          # 1..100
        h.observe(float(v))
    s = h.summary()
    assert s["count"] == 100
    assert s["min"] == 1.0 and s["max"] == 100.0
    # log buckets give ~4% relative resolution
    assert abs(s["p50"] - 50) / 50 < 0.10
    assert abs(s["p95"] - 95) / 95 < 0.10
    assert abs(s["p99"] - 99) / 99 < 0.10
    assert s["p50"] <= s["p95"] <= s["p99"] <= s["max"]


def test_histogram_nonpositive_and_empty():
    h = metrics.Histogram()
    assert np.isnan(h.percentile(0.5))
    h.observe(0.0)
    h.observe(-1.0)
    assert h.count == 2
    assert h.percentile(0.5) == -1.0  # underflow bucket reports min


def test_registry_merge_cross_process_shape():
    r = metrics.Registry()
    h = r.histogram("x_s")
    for v in (0.1, 0.2, 0.3):
        h.observe(v)
    r.counter("n").inc(5)
    r2 = metrics.Registry()
    r2.merge(json.loads(json.dumps(r.to_dict())))
    d = r2.to_dict()
    assert d["counters"]["n"] == 5
    assert d["histograms"]["x_s"]["count"] == 3
    assert d["histograms"]["x_s"]["min"] == pytest.approx(0.1)


def test_registry_reset_between_tests_a():
    # with the autouse fixture, this name must not exist yet
    assert "leak.probe" not in metrics.get_registry().names()
    metrics.counter("leak.probe").inc()


def test_registry_reset_between_tests_b():
    # ordering with _a doesn't matter: neither test may see the other's state
    assert "leak.probe" not in metrics.get_registry().names()
    metrics.counter("leak.probe").inc()


# ----------------------------------------------------------------- timing
def test_timeit_records_span_and_histogram():
    res = timeit(lambda: sum(range(100)), reps=3, warmup=1, name="t.work")
    assert len(res.times) == 3
    assert res.best <= res.mean
    assert len(trace.get_tracer().spans("bench.t.work")) == 3
    assert metrics.export()["histograms"]["t.work_s"]["count"] == 3


# -------------------------------------------------------------- reconcile
def test_reconcile_smoke_8dev_all_registry_strategies():
    """reconcile.run on a 2x2x2 mesh probes every PROBED strategy and
    emits all four terms per strategy — no silently missing rows."""
    out = run_with_devices(
        """
import json
import jax
import numpy as np
from repro.core import Domain, clustered_events
from repro.obs import reconcile

dom = Domain(gx=48.0, gy=48.0, gt=16.0, sres=1.0, tres=1.0, hs=3.0, ht=2.0)
pts = clustered_events(1500, dom, seed=0)
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
res = reconcile.run(pts, dom, mesh, reps=1)
res["_probed"] = list(reconcile.PROBED)
print("RESULT" + json.dumps(res))
""",
        n_devices=8,
    )
    line = [ln for ln in out.splitlines() if ln.startswith("RESULT")][0]
    res = json.loads(line[len("RESULT"):])
    strategies = {r["strategy"] for r in res["rows"]}
    assert strategies == set(res["_probed"])
    assert {"dr", "dd", "pd", "pd_xt", "pd_xyt", "dd_lpt",
            "hybrid"} <= strategies
    for strat in strategies:
        terms = {r["term"] for r in res["rows"] if r["strategy"] == strat}
        assert terms == reconcile_terms(), (strat, terms)
    for r in res["rows"]:
        assert r["measured_s"] >= 0
        if r["predicted_s"] is not None:
            assert r["rel_err"] is not None
    assert "strategy" in res["report"]


def test_measure_strategy_error_lists_registry_keys():
    from repro.obs import reconcile

    with pytest.raises(ValueError) as ei:
        reconcile.measure_strategy(
            np.zeros((1, 3), np.float32), None, None, "nope")
    for name in reconcile.PROBED:
        assert name in str(ei.value)


def reconcile_terms():
    from repro.obs import reconcile

    return set(reconcile.TERMS)
