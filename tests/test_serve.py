"""Serving engine tests: bucket batching, stopping, decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.models import init_params, forward
from repro.resilience import faults
from repro.serve import ServingEngine, EngineConfig, cache_bytes


@pytest.fixture(autouse=True)
def _no_ambient_faults():
    """These are exact-output tests, not chaos tests: neutralize any
    ambient REPRO_FAULTS so the CI serve job can run them inside its
    chaos matrix (the chaos coverage lives in test_serve_continuous /
    test_resilience, which configure the injector explicitly)."""
    faults.configure("", 0)
    yield


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(ARCHS["smollm-360m"])
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_engine_greedy_batch(setup):
    cfg, params = setup
    eng = ServingEngine(cfg, params, EngineConfig(max_batch=4, max_seq=64))
    rng = np.random.default_rng(0)
    for uid in range(6):          # two buckets: len 8 and len 12
        L = 8 if uid % 2 == 0 else 12
        eng.submit(uid, rng.integers(0, cfg.vocab, L), max_new=5)
    out = eng.run()
    assert set(out) == set(range(6))
    assert all(len(v) == 5 for v in out.values())


def test_engine_matches_forward_greedy(setup):
    """Engine's greedy continuation == argmax over teacher-forced forward."""
    cfg, params = setup
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab, 8)
    eng = ServingEngine(cfg, params, EngineConfig(max_batch=1, max_seq=64))
    eng.submit(0, prompt, max_new=4)
    got = eng.run()[0]
    # reference: iteratively extend with full forward
    seq = list(prompt)
    want = []
    for _ in range(4):
        logits, _ = forward(cfg, params, jnp.asarray([seq]))
        t = int(jnp.argmax(logits[0, -1]))
        want.append(t)
        seq.append(t)
    assert list(got) == want, (list(got), want)


def test_eos_stops(setup):
    cfg, params = setup
    # find the first greedily generated token and use it as "eos"
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, cfg.vocab, 8)
    eng = ServingEngine(cfg, params, EngineConfig(max_batch=1, max_seq=64))
    eng.submit(0, prompt, max_new=8)
    first = eng.run()[0]
    eos = int(first[1]) if len(first) > 1 else int(first[0])
    eng2 = ServingEngine(cfg, params,
                         EngineConfig(max_batch=1, max_seq=64, eos_id=eos))
    eng2.submit(0, prompt, max_new=8)
    out = eng2.run()[0]
    assert len(out) <= len(first)
    assert eos in list(out) or len(out) == 8


def test_cache_bytes_sane():
    full = ARCHS["mistral-nemo-12b"]
    b = cache_bytes(full, batch=1, seq=32768)
    # 40L * 32768 * 8kv * 128dh * 2(kv) * 2B = ~5.4GB
    assert 4e9 < b < 8e9
    rw = cache_bytes(ARCHS["rwkv6-3b"], batch=1, seq=32768)
    assert rw < 1e9    # state-based: constant in seq


def test_temperature_sampling_differs(setup):
    cfg, params = setup
    import numpy as np
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab, 8)
    outs = []
    for seed in (1, 2):
        eng = ServingEngine(cfg, params, EngineConfig(
            max_batch=1, max_seq=64, temperature=1.5, seed=seed))
        eng.submit(0, prompt, max_new=8)
        outs.append(list(eng.run()[0]))
    # different seeds should (overwhelmingly) sample different continuations
    assert outs[0] != outs[1]
