"""Resilience layer tests: deterministic fault injection, retry/backoff,
checkpoint corruption fallback, API validation, serve chaos completion,
and distributed strategy fallback."""
import os
import zlib

import numpy as np
import pytest

from repro.core import Domain, clustered_events
from repro.core.api import stkde, validate_inputs
from repro.obs import metrics
from repro.resilience import (
    AdmissionError,
    CheckpointCorruptError,
    DeadlineExceededError,
    DegradePolicy,
    ReproValidationError,
    RetriesExhaustedError,
    RetryPolicy,
    degrade,
    errors,
    faults,
    run_with_degrade,
    with_retry,
)
from util_subproc import run_with_devices

DOM = Domain(gx=24.0, gy=24.0, gt=8.0, sres=1.0, tres=1.0, hs=3.0, ht=2.0)

# every named site at >= 10% — the acceptance-criterion chaos spec;
# the CI chaos job overrides via the real REPRO_FAULTS env var
CHAOS_SPEC = os.environ.get(
    "REPRO_FAULTS",
    "serve.prefill:oom:0.15,serve.decode:nan:0.10,dist.halo:nan:0.15,"
    "ckpt.write:corrupt:0.25,data.read:drop:0.10",
)
CHAOS_SEED = int(os.environ.get("REPRO_FAULTS_SEED", "42"))


# ------------------------------------------------------------ injector
class TestFaultInjector:
    def test_deterministic_under_seed(self):
        def decisions(seed):
            inj = faults.FaultInjector(
                faults.parse_spec("serve.decode:oom:0.3"), seed=seed)
            out = []
            for _ in range(50):
                try:
                    inj.maybe_fail("serve.decode")
                    out.append(0)
                except errors.InjectedOOMError:
                    out.append(1)
            return out

        a, b = decisions(7), decisions(7)
        assert a == b
        assert sum(a) > 0
        assert decisions(8) != a  # a different seed reshuffles faults

    def test_rate_respected(self):
        inj = faults.FaultInjector(
            faults.parse_spec("data.read:drop:0.2"), seed=0)
        n_fail = 0
        for _ in range(500):
            try:
                inj.maybe_fail("data.read")
            except errors.InjectedDropError:
                n_fail += 1
        assert 0.1 < n_fail / 500 < 0.3

    def test_sites_independent(self):
        inj = faults.FaultInjector(
            faults.parse_spec("serve.prefill:oom:1.0"), seed=0)
        inj.maybe_fail("serve.decode")  # unconfigured site never fires
        with pytest.raises(errors.InjectedOOMError):
            inj.maybe_fail("serve.prefill")

    def test_corrupt_and_poison(self):
        inj = faults.FaultInjector(
            faults.parse_spec("ckpt.write:corrupt:1.0,dist.halo:nan:1.0"),
            seed=1)
        data = bytes(range(256)) * 8
        assert inj.corrupt("ckpt.write", data) != data
        arr = np.ones((4, 4), np.float32)
        assert np.isnan(np.asarray(inj.poison("dist.halo", arr))).any()
        # untriggered sites pass data through untouched
        assert inj.corrupt("data.read", data) == data
        assert not np.isnan(np.asarray(inj.poison("serve.decode",
                                                  arr))).any()

    def test_spec_validation(self):
        with pytest.raises(ReproValidationError):
            faults.parse_spec("serve.prefill:oom")
        with pytest.raises(ReproValidationError):
            faults.parse_spec("serve.prefill:explode:0.5")
        with pytest.raises(ReproValidationError):
            faults.parse_spec("serve.prefill:oom:1.5")
        assert len(faults.parse_spec("*:drop:0.1")) == len(faults.SITES)
        assert faults.parse_spec("") == []

    def test_injection_counters(self):
        inj = faults.FaultInjector(
            faults.parse_spec("serve.prefill:oom:1.0"), seed=0)
        with pytest.raises(errors.InjectedOOMError):
            inj.maybe_fail("serve.prefill")
        c = metrics.export()["counters"]
        assert c["resilience.injected"] == 1
        assert c["resilience.injected.serve.prefill"] == 1


# -------------------------------------------------------------- retry
class TestRetry:
    def test_succeeds_after_transient(self):
        calls = [0]

        def flaky():
            calls[0] += 1
            if calls[0] < 3:
                raise errors.InjectedDropError("x")
            return "ok"

        out = with_retry(flaky, RetryPolicy(max_attempts=4),
                         site="t", sleep=lambda d: None)
        assert out == "ok" and calls[0] == 3
        c = metrics.export()["counters"]
        assert c["resilience.retries"] == 2

    def test_backoff_deterministic_and_bounded(self):
        pol = RetryPolicy(max_attempts=6, base_delay_s=0.01,
                          max_delay_s=0.05, multiplier=2.0, jitter=0.5,
                          seed=3)
        a = list(pol.delays("site"))
        b = list(pol.delays("site"))
        assert a == b and len(a) == 5
        assert all(0 < d <= 0.05 for d in a)
        # jitter shrinks the nominal delay, never grows it
        noj = list(RetryPolicy(max_attempts=6, base_delay_s=0.01,
                               max_delay_s=0.05, jitter=0.0).delays("s"))
        assert all(x <= y for x, y in zip(a, noj))

    def test_gives_up_with_cause(self):
        def always():
            raise errors.InjectedOOMError("s")

        with pytest.raises(RetriesExhaustedError) as ei:
            with_retry(always, RetryPolicy(max_attempts=3),
                       site="s", sleep=lambda d: None)
        assert isinstance(ei.value.__cause__, errors.InjectedOOMError)
        assert metrics.export()["counters"]["resilience.gave_up"] == 1

    def test_nontransient_passes_through(self):
        def bug():
            raise KeyError("not transient")

        with pytest.raises(KeyError):
            with_retry(bug, sleep=lambda d: None)
        assert "resilience.retries" not in metrics.export()["counters"]

    def test_deadline(self):
        def always():
            raise errors.InjectedDropError("s")

        with pytest.raises(DeadlineExceededError):
            with_retry(
                always,
                RetryPolicy(max_attempts=100, base_delay_s=10.0,
                            deadline_s=0.001),
                sleep=lambda d: None,
            )

    def test_retry_on_extra_types(self):
        calls = [0]

        def flaky():
            calls[0] += 1
            if calls[0] == 1:
                raise KeyError("custom transient")
            return 1

        assert with_retry(flaky, RetryPolicy(retry_on=(KeyError,)),
                          sleep=lambda d: None) == 1


# ------------------------------------------------------------ degrade
class TestDegrade:
    def test_full_fidelity_untouched(self):
        pts = clustered_events(200, DOM, seed=0)
        res = run_with_degrade(lambda p, d: stkde(p, d), pts, DOM)
        assert not res.degraded and res.level == 0
        assert res.error_bound == 0.0
        assert res.grid.shape == DOM.grid_shape

    def test_degrades_on_resource_failure(self):
        pts = clustered_events(200, DOM, seed=0)
        calls = [0]

        def compute(p, d):
            calls[0] += 1
            if calls[0] == 1:
                raise errors.InjectedOOMError("stkde")
            return stkde(p, d)

        res = run_with_degrade(compute, pts, DOM,
                               DegradePolicy(coarsen=2.0, subsample=0.5))
        assert res.degraded and res.level == 1
        assert res.error_bound > 0
        assert res.dom.sres == 2.0 * DOM.sres
        assert len(res.reason) > 0
        assert res.grid.shape == res.dom.grid_shape
        assert metrics.export()["counters"]["resilience.degraded"] == 1

    def test_runs_out_of_levels(self):
        pts = clustered_events(50, DOM, seed=0)

        def never(p, d):
            raise errors.InjectedOOMError("stkde")

        with pytest.raises(errors.InjectedOOMError):
            run_with_degrade(never, pts, DOM, DegradePolicy(max_levels=1))

    def test_nonfinite_output_triggers_degrade(self):
        pts = clustered_events(100, DOM, seed=0)
        calls = [0]

        def compute(p, d):
            calls[0] += 1
            g = np.asarray(stkde(p, d))
            if calls[0] == 1:
                g = g.copy()
                g[0, 0, 0] = np.nan
            return g

        res = run_with_degrade(compute, pts, DOM)
        assert res.degraded and "NonFiniteOutputError" in res.reason

    def test_error_bound_monotonic(self):
        pol = DegradePolicy(coarsen=2.0, subsample=0.5, max_levels=3)
        bounds = [degrade.error_bound(DOM, 1000, lv, pol)
                  for lv in range(4)]
        assert bounds[0] == 0.0
        assert all(a < b for a, b in zip(bounds, bounds[1:]))

    def test_subsample_deterministic(self):
        pts = clustered_events(100, DOM, seed=0)
        a = degrade.subsample_points(pts, 0.3, seed=5)
        b = degrade.subsample_points(pts, 0.3, seed=5)
        assert np.array_equal(a, b) and len(a) == 30


# --------------------------------------------------------- validation
class TestApiValidation:
    def test_rejects_nan_inf(self):
        with pytest.raises(ReproValidationError, match="NaN/Inf"):
            validate_inputs(np.array([[np.nan, 1.0, 1.0]]), DOM)
        with pytest.raises(ReproValidationError, match="NaN/Inf"):
            validate_inputs(np.array([[np.inf, 1.0, 1.0]]), DOM)

    def test_rejects_empty_and_misshapen(self):
        with pytest.raises(ReproValidationError, match="empty"):
            validate_inputs(np.zeros((0, 3)), DOM)
        with pytest.raises(ReproValidationError, match="shape"):
            validate_inputs(np.zeros((5, 2)), DOM)

    def test_rejects_bad_bandwidth_and_resolution(self):
        import dataclasses

        pts = np.array([[1.0, 1.0, 1.0]])
        with pytest.raises(ReproValidationError, match="bandwidth"):
            validate_inputs(pts, dataclasses.replace(DOM, hs=0.0))
        with pytest.raises(ReproValidationError, match="bandwidth"):
            validate_inputs(pts, dataclasses.replace(DOM, ht=-1.0))
        with pytest.raises(ReproValidationError, match="resolution"):
            validate_inputs(pts, dataclasses.replace(DOM, sres=0.0))

    def test_rejects_out_of_window_times(self):
        with pytest.raises(ReproValidationError, match="time window"):
            validate_inputs(np.array([[1.0, 1.0, 100.0]]), DOM)
        # one bandwidth outside is still in range (density radiates in)
        validate_inputs(np.array([[1.0, 1.0, -1.0]]), DOM)

    def test_stkde_validates_by_default(self):
        with pytest.raises(ReproValidationError):
            stkde(np.zeros((0, 3)), DOM)


# --------------------------------------------------------- checkpoint
class TestCheckpointCorruption:
    def _trees(self):
        t1 = {"w": np.arange(12.0).reshape(3, 4), "b": np.ones(4)}
        t2 = {"w": t1["w"] * 2, "b": t1["b"] * 2}
        return t1, t2

    def test_bitflip_falls_back_to_previous(self, tmp_path):
        from repro.train import checkpoint as ckpt

        t1, t2 = self._trees()
        ckpt.save(str(tmp_path), 1, t1)
        ckpt.save(str(tmp_path), 2, t2)
        p = tmp_path / "step_00000002" / "arrays.npz"
        raw = bytearray(p.read_bytes())
        raw[len(raw) // 2] ^= 0xFF
        p.write_bytes(bytes(raw))
        assert not ckpt.verify(str(tmp_path), 2)
        out, step, _ = ckpt.restore(str(tmp_path), t1)
        assert step == 1
        np.testing.assert_array_equal(out["w"], t1["w"])
        c = metrics.export()["counters"]
        assert c["resilience.ckpt_fallback"] == 1

    def test_truncation_falls_back(self, tmp_path):
        from repro.train import checkpoint as ckpt

        t1, t2 = self._trees()
        ckpt.save(str(tmp_path), 1, t1)
        ckpt.save(str(tmp_path), 2, t2)
        p = tmp_path / "step_00000002" / "arrays.npz"
        p.write_bytes(p.read_bytes()[:20])
        out, step, _ = ckpt.restore(str(tmp_path), t1)
        assert step == 1

    def test_all_corrupt_raises_typed(self, tmp_path):
        from repro.train import checkpoint as ckpt

        t1, _ = self._trees()
        ckpt.save(str(tmp_path), 1, t1)
        p = tmp_path / "step_00000001" / "arrays.npz"
        p.write_bytes(b"junk")
        with pytest.raises(CheckpointCorruptError):
            ckpt.restore(str(tmp_path), t1)

    def test_explicit_step_is_strict(self, tmp_path):
        from repro.train import checkpoint as ckpt

        t1, t2 = self._trees()
        ckpt.save(str(tmp_path), 1, t1)
        ckpt.save(str(tmp_path), 2, t2)
        p = tmp_path / "step_00000002" / "arrays.npz"
        raw = bytearray(p.read_bytes())
        raw[-5] ^= 0x01
        p.write_bytes(bytes(raw))
        with pytest.raises(CheckpointCorruptError):
            ckpt.restore(str(tmp_path), t1, step=2)

    def test_injected_write_corruption_retried(self, tmp_path):
        from repro.train import checkpoint as ckpt

        t1, _ = self._trees()
        faults.configure("ckpt.write:corrupt:0.5", seed=11)
        for s in range(1, 6):
            ckpt.save(str(tmp_path), s, t1, keep=3)
        assert ckpt.all_steps(str(tmp_path)) == [3, 4, 5]
        assert all(ckpt.verify(str(tmp_path), s) for s in (3, 4, 5))
        c = metrics.export()["counters"]
        assert c["resilience.injected.ckpt.write"] >= 1
        assert c.get("resilience.retries.ckpt.write", 0) >= 1

    def test_checksum_recorded(self, tmp_path):
        import json

        from repro.train import checkpoint as ckpt

        t1, _ = self._trees()
        ckpt.save(str(tmp_path), 1, t1)
        man = json.loads(
            (tmp_path / "step_00000001" / "manifest.json").read_text())
        payload = (tmp_path / "step_00000001" / "arrays.npz").read_bytes()
        assert man["checksum_crc32"] == zlib.crc32(payload)


# --------------------------------------------------------------- data
class TestDataPipeline:
    def test_read_faults_retried_and_deterministic(self):
        from repro.data import DataConfig, SyntheticLM

        cfg = DataConfig(vocab=64, seq_len=16, global_batch=4)
        clean = SyntheticLM(cfg).batch_at(3)
        faults.configure("data.read:drop:0.4", seed=5)
        chaotic = SyntheticLM(cfg).batch_at(3)
        np.testing.assert_array_equal(clean["tokens"], chaotic["tokens"])
        c = metrics.export()["counters"]
        assert c.get("resilience.retries.data.read", 0) >= 0  # seed-dep

    def test_stream_survives_drops(self):
        from repro.core import get_instance
        from repro.data import stkde_stream

        inst = get_instance("Dengue_Lr-Lb").scaled(max_points=600)
        faults.configure("data.read:drop:0.3", seed=2)
        chunks = [p for p, _ in stkde_stream(inst, chunk=200)]
        assert sum(len(c) for c in chunks) == inst.n


# -------------------------------------------------------- serve chaos
@pytest.fixture(scope="module")
def lm_setup():
    import jax

    from repro.configs import ARCHS, reduced
    from repro.models import init_params

    cfg = reduced(ARCHS["smollm-360m"])
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


class TestServeResilience:
    def test_admission_bounded(self, lm_setup):
        from repro.serve import EngineConfig, ServingEngine

        cfg, params = lm_setup
        eng = ServingEngine(cfg, params,
                            EngineConfig(max_batch=2, max_seq=64,
                                         max_queue=3))
        rng = np.random.default_rng(0)
        for uid in range(3):
            eng.submit(uid, rng.integers(0, cfg.vocab, 8), max_new=2)
        with pytest.raises(AdmissionError) as ei:
            eng.submit(3, rng.integers(0, cfg.vocab, 8))
        assert ei.value.reason == "queue_full"
        assert metrics.export()["counters"]["serve.rejected"] == 1
        out = eng.run()  # queue drains; next submit admitted again
        assert set(out) == {0, 1, 2}
        eng.submit(4, rng.integers(0, cfg.vocab, 8), max_new=2)

    def test_submit_validation(self, lm_setup):
        from repro.serve import EngineConfig, ServingEngine

        cfg, params = lm_setup
        eng = ServingEngine(cfg, params,
                            EngineConfig(max_batch=2, max_seq=16))
        with pytest.raises(ReproValidationError):
            eng.submit(0, np.array([], np.int32))
        with pytest.raises(ReproValidationError):
            eng.submit(0, np.zeros(32, np.int32))       # > max_seq
        with pytest.raises(ReproValidationError):
            eng.submit(0, np.array([1, -2, 3]))          # negative token
        with pytest.raises(ReproValidationError):
            eng.submit(0, np.array([1, cfg.vocab + 5]))  # over vocab
        with pytest.raises(ReproValidationError):
            eng.submit(0, np.array([np.nan, 1.0]))
        with pytest.raises(ReproValidationError):
            eng.submit(0, np.array([1, 2]), max_new=0)
        assert eng.queue == []

    def test_chaos_completes_every_request(self, lm_setup):
        """Acceptance criterion: >=10% injection at every named site, all
        requests terminate (ok / degraded / typed-failed), no raises."""
        from repro.serve import EngineConfig, ServingEngine

        cfg, params = lm_setup

        def chaos_run():
            faults.configure(CHAOS_SPEC, seed=CHAOS_SEED)
            eng = ServingEngine(
                cfg, params,
                EngineConfig(max_batch=4, max_seq=64, max_queue=32))
            rng = np.random.default_rng(0)
            for uid in range(10):
                L = 8 if uid % 2 == 0 else 12
                eng.submit(uid, rng.integers(0, cfg.vocab, L), max_new=4)
            return eng.run_detailed()

        res = chaos_run()
        assert set(res) == set(range(10))
        for r in res.values():
            assert r.ok or (r.degraded and r.reason), r
            assert isinstance(r.tokens, np.ndarray)
        # determinism: a fresh engine + freshly seeded injector replays
        # the exact same faults and produces the same outcome
        res2 = chaos_run()
        assert {u: (r.ok, r.degraded, r.tokens.tolist())
                for u, r in res.items()} == \
               {u: (r.ok, r.degraded, r.tokens.tolist())
                for u, r in res2.items()}

    def test_request_timeout_degrades(self, lm_setup):
        from repro.serve import EngineConfig, ServingEngine

        cfg, params = lm_setup
        eng = ServingEngine(
            cfg, params,
            EngineConfig(max_batch=1, max_seq=64,
                         request_timeout_s=1e-6))  # expires immediately
        eng.submit(0, np.arange(8) % cfg.vocab, max_new=16)
        res = eng.run_detailed()
        assert res[0].degraded and res[0].reason == "deadline_truncated"
        assert len(res[0].tokens) < 16

    def test_unbatchable_poison_degrades_to_solo(self, lm_setup):
        """A 100% decode-NaN site sinks every attempt; the engine must
        still terminate each request with a typed failure."""
        from repro.serve import EngineConfig, ServingEngine

        cfg, params = lm_setup
        faults.configure("serve.decode:nan:1.0", seed=0)
        eng = ServingEngine(
            cfg, params,
            EngineConfig(max_batch=4, max_seq=64,
                         retry=RetryPolicy(max_attempts=2,
                                           base_delay_s=0.001)))
        rng = np.random.default_rng(1)
        for uid in range(4):
            eng.submit(uid, rng.integers(0, cfg.vocab, 8), max_new=3)
        res = eng.run_detailed()
        assert set(res) == set(range(4))
        for r in res.values():
            assert not r.ok and r.degraded
            assert "NonFinite" in r.reason or "Retries" in r.reason
        c = metrics.export()["counters"]
        assert c["serve.failed"] == 4


# -------------------------------------------------- distributed chaos
def test_distributed_fallback_to_dr():
    """An injected halo fault (NaN or OOM) must reroute pd to dr with an
    answer identical to the reference."""
    code = """
import numpy as np, jax
from jax.sharding import AxisType
from repro.core import Domain, pb, clustered_events
from repro.core.api import stkde
from repro.resilience import faults
from repro.obs import metrics
dom = Domain(gx=40., gy=36., gt=10., sres=1., tres=1., hs=2., ht=1.)
pts = clustered_events(500, dom, seed=9)
want = np.asarray(pb(pts, dom))
mesh = jax.make_mesh((4, 2), ("data", "model"),
                     axis_types=(AxisType.Auto,)*2)
for kind in ("nan", "oom"):
    faults.configure(f"dist.halo:{kind}:1.0", seed=0)
    got = stkde(pts, dom, mesh=mesh, strategy="pd")
    d = np.abs(np.asarray(got) - want).max()
    assert d < 5e-7, (kind, d)
    print(kind, "fallback ok", d)
c = metrics.export()["counters"]
assert c["resilience.fallbacks"] == 2, c
assert c["resilience.fallbacks.stkde.pd"] == 2, c
"""
    out = run_with_devices(code, 8)
    assert "nan fallback ok" in out and "oom fallback ok" in out


def test_distributed_chaos_rate_still_serves():
    """Acceptance-style: nonzero halo injection rate, every query answered
    and exact (fallback or clean path)."""
    code = """
import numpy as np, jax
from jax.sharding import AxisType
from repro.core import Domain, pb, clustered_events
from repro.core.api import stkde
from repro.resilience import faults
dom = Domain(gx=40., gy=36., gt=10., sres=1., tres=1., hs=2., ht=1.)
pts = clustered_events(400, dom, seed=4)
want = np.asarray(pb(pts, dom))
mesh = jax.make_mesh((4, 2), ("data", "model"),
                     axis_types=(AxisType.Auto,)*2)
faults.configure("dist.halo:nan:0.3", seed=13)
for q in range(6):
    got = stkde(pts, dom, mesh=mesh, strategy="pd")
    d = np.abs(np.asarray(got) - want).max()
    assert d < 5e-7, (q, d)
print("all queries ok")
"""
    out = run_with_devices(code, 8)
    assert "all queries ok" in out
