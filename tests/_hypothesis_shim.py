"""Deterministic fallback for the tiny hypothesis subset the tests use.

The container does not ship ``hypothesis`` (and installing packages is not
an option), so ``conftest.py`` registers this module as ``hypothesis`` when
the real one is missing. It covers exactly what the suite uses — ``@given``
with ``floats``/``integers`` strategies and ``@settings(max_examples=...,
deadline=...)`` — by running each property ``max_examples`` times with
seeded pseudo-random draws, so the property tests still exercise a spread of
inputs and failures reproduce exactly. When the real hypothesis is
installed it is always preferred.
"""
from __future__ import annotations

import functools
import inspect
import random
import sys
import types


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example_with(self, rng: random.Random):
        return self._draw(rng)


def floats(min_value: float, max_value: float) -> _Strategy:
    return _Strategy(lambda rng: rng.uniform(min_value, max_value))


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def settings(max_examples: int = 10, deadline=None, **_ignored):
    def deco(fn):
        fn._shim_max_examples = max_examples
        return fn

    return deco


def given(**strategies_kw):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args):
            n = getattr(wrapper, "_shim_max_examples", 10)
            for i in range(n):
                rng = random.Random(f"{fn.__module__}.{fn.__qualname__}:{i}")
                drawn = {
                    k: s.example_with(rng)
                    for k, s in strategies_kw.items()
                }
                fn(*args, **drawn)

        # hide the drawn parameters from pytest's fixture resolution
        sig = inspect.signature(fn)
        kept = [p for name, p in sig.parameters.items()
                if name not in strategies_kw]
        wrapper.__signature__ = sig.replace(parameters=kept)
        return wrapper

    return deco


def install():
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    mod.HealthCheck = types.SimpleNamespace(too_slow="too_slow")
    st = types.ModuleType("hypothesis.strategies")
    st.floats = floats
    st.integers = integers
    mod.strategies = st
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st
