"""Every module under ``repro`` must import cleanly on the installed JAX.

Guards against version-skew regressions (e.g. ``from jax import shard_map``
on a JAX without it) anywhere in the tree, including modules no other test
touches.
"""
import importlib
import pkgutil

import pytest

import repro


def _all_modules():
    names = ["repro"]
    for m in pkgutil.walk_packages(repro.__path__, prefix="repro."):
        names.append(m.name)
    return sorted(names)


@pytest.mark.parametrize("name", _all_modules())
def test_module_imports(name):
    importlib.import_module(name)
