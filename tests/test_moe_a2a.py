"""All-to-all MoE: equivalence with the GSPMD path (fwd + grad)."""
import textwrap
from util_subproc import run_with_devices


def test_a2a_equals_gspmd_fwd_and_grad():
    code = textwrap.dedent("""
    import jax, jax.numpy as jnp
    from jax.sharding import AxisType
    from repro.models.config import ModelConfig
    from repro.models import moe

    cfg = ModelConfig(name="t", family="moe", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=4, d_ff=128, vocab=256,
                      mlp="moe", n_experts=8, top_k=2, d_ff_expert=32,
                      capacity_factor=8.0)
    p = moe.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 64)) * 0.5
    mesh = jax.make_mesh((4, 2), ("data", "model"),
                         axis_types=(AxisType.Auto,)*2)
    want, aux_w = moe.moe_apply(cfg, p, x)
    got, aux_g = jax.jit(
        lambda p, x: moe.moe_apply_a2a(cfg, p, x, mesh))(p, x)
    assert float(jnp.abs(got - want).max()) < 1e-6
    assert abs(float(aux_w) - float(aux_g)) < 1e-6

    def loss(p, x, impl):
        y, aux = (moe.moe_apply_a2a(cfg, p, x, mesh) if impl == "a2a"
                  else moe.moe_apply(cfg, p, x))
        return (y ** 2).mean() + aux

    g1 = jax.grad(loss)(p, x, "gspmd")
    g2 = jax.jit(lambda p, x: jax.grad(loss)(p, x, "a2a"))(p, x)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        assert float(jnp.abs(a - b).max()) < 1e-6
    print("a2a == gspmd fwd+grad")
    """)
    run_with_devices(code, 8)


def test_a2a_fallback_when_indivisible():
    code = textwrap.dedent("""
    import jax, jax.numpy as jnp
    from jax.sharding import AxisType
    from repro.models.config import ModelConfig
    from repro.models import moe

    # n_experts=6 not divisible by model axis 2 -> falls back, still correct
    cfg = ModelConfig(name="t", family="moe", n_layers=2, d_model=32,
                      n_heads=2, n_kv_heads=2, d_ff=64, vocab=128,
                      mlp="moe", n_experts=6, top_k=2, d_ff_expert=16,
                      capacity_factor=8.0)
    p = moe.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 32)) * 0.5
    mesh = jax.make_mesh((2, 4), ("data", "model"),
                         axis_types=(AxisType.Auto,)*2)
    want, _ = moe.moe_apply(cfg, p, x)
    got, _ = moe.moe_apply_a2a(cfg, p, x, mesh)
    assert float(jnp.abs(got - want).max()) < 1e-6
    print("fallback ok")
    """)
    run_with_devices(code, 8)
