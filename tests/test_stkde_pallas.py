"""Pallas tile-kernel sweep tests: kernel (interpret mode) vs pure-jnp oracle
vs the independent scatter formulation (core.pb)."""
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import Domain, pb, clustered_events, bucketing
from repro.core import kernels_math as km
from repro.kernels import stkde_tiled
from repro.kernels.ref import stkde_tiles_ref
from repro.kernels.stkde_tile import stkde_tiles_pallas


def _make(dom, n, seed):
    return clustered_events(n, dom, seed=seed)


# ----------------------------------------------------------- shape sweeps
TILE_CASES = [
    # (grid, hs, ht, tile)
    ((33, 25, 17), 3.0, 2.0, (8, 8, 8)),
    ((32, 32, 16), 4.0, 1.0, (16, 16, 8)),
    ((64, 48, 12), 6.0, 3.0, (32, 16, 4)),
    ((17, 19, 23), 2.0, 2.0, (8, 8, 16)),  # ragged: tiles overhang the grid
    ((40, 40, 8), 5.0, 1.0, (40, 40, 8)),  # single tile
]


@pytest.mark.parametrize("grid,hs,ht,tile", TILE_CASES)
def test_kernel_vs_scatter_sweep(grid, hs, ht, tile):
    dom = Domain(
        gx=float(grid[0]), gy=float(grid[1]), gt=float(grid[2]),
        sres=1.0, tres=1.0, hs=hs, ht=ht,
    )
    pts = _make(dom, 400, seed=hash(grid) % 1000)
    want = np.asarray(pb(pts, dom))
    got = np.asarray(stkde_tiled(pts, dom, tile=tile))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-8)


@pytest.mark.parametrize("chunk", [8, 64, 256])
def test_kernel_chunk_sizes(chunk):
    dom = Domain(gx=32, gy=32, gt=16, sres=1.0, tres=1.0, hs=3.0, ht=2.0)
    pts = _make(dom, 600, seed=11)
    want = np.asarray(stkde_tiled(pts, dom, use_ref=True))
    got = np.asarray(stkde_tiled(pts, dom, chunk=chunk))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-9)


def test_kernel_nonunit_resolution_and_origin():
    dom = Domain(
        gx=20.0, gy=15.0, gt=30.0, sres=0.6, tres=2.2, hs=2.0, ht=4.0,
        ox=-7.0, oy=3.0, ot=100.0,
    )
    rng = np.random.default_rng(4)
    pts = np.stack(
        [
            -7.0 + rng.random(300) * 20.0,
            3.0 + rng.random(300) * 15.0,
            100.0 + rng.random(300) * 30.0,
        ],
        axis=1,
    ).astype(np.float32)
    want = np.asarray(pb(pts, dom))
    got = np.asarray(stkde_tiled(pts, dom))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-8)


def test_kernel_paper_verbatim_kernel_funcs():
    dom = Domain(gx=24, gy=24, gt=12, sres=1.0, tres=1.0, hs=3.0, ht=2.0)
    pts = _make(dom, 200, seed=13)
    kw = dict(ks=km.ks_paper_verbatim, kt=km.kt_paper_verbatim)
    want = np.asarray(pb(pts, dom, variant="sym", **kw))
    got = np.asarray(stkde_tiled(pts, dom, **kw))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-8)


@settings(max_examples=10, deadline=None)
@given(
    n=st.integers(1, 300),
    hs=st.floats(1.0, 5.0),
    ht=st.floats(1.0, 3.0),
    seed=st.integers(0, 99),
)
def test_property_kernel_equals_scatter(n, hs, ht, seed):
    dom = Domain(gx=26, gy=22, gt=18, sres=1.0, tres=1.0, hs=hs, ht=ht)
    pts = _make(dom, n, seed=seed)
    want = np.asarray(pb(pts, dom))
    got = np.asarray(stkde_tiled(pts, dom))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-7)


def test_empty_tiles_are_zero():
    """Points concentrated in one corner leave far tiles exactly zero."""
    dom = Domain(gx=64, gy=64, gt=16, sres=1.0, tres=1.0, hs=2.0, ht=1.0)
    pts = np.full((50, 3), 3.0, dtype=np.float32)
    grid = np.asarray(stkde_tiled(pts, dom))
    assert grid[10:, 10:, :].sum() == 0.0
    assert grid[:8, :8, :8].sum() > 0


def test_dtype_is_f32_accumulation():
    dom = Domain(gx=16, gy=16, gt=8, sres=1.0, tres=1.0, hs=2.0, ht=1.0)
    pts = _make(dom, 100, seed=17)
    out = stkde_tiled(pts, dom)
    assert out.dtype == jnp.float32
