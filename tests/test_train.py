"""Training substrate tests: optimizer, loss descent, checkpoint/restart
equivalence, crash-resume, async checkpointing, gradient compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.data import DataConfig, SyntheticLM
from repro.models import init_params
from repro.train import (
    OptimizerConfig, RunnerConfig, TrainRunner, make_train_step,
    checkpoint as ckpt, optimizer as opt,
)


def tiny_setup(arch="smollm-360m", steps=100):
    cfg = reduced(ARCHS[arch]).replace(vocab=256)
    data = SyntheticLM(DataConfig(vocab=256, seq_len=64, global_batch=16))
    params = init_params(cfg, jax.random.PRNGKey(0))
    ocfg = OptimizerConfig(lr=1e-2, warmup_steps=10, total_steps=steps)
    step_fn = jax.jit(make_train_step(cfg, ocfg))
    return cfg, data, params, ocfg, step_fn


class TestOptimizer:
    def test_lr_schedule_shape(self):
        ocfg = OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=100,
                               min_lr_frac=0.1)
        assert float(opt.lr_at(ocfg, 0)) == 0.0
        assert abs(float(opt.lr_at(ocfg, 10)) - 1.0) < 0.11
        assert abs(float(opt.lr_at(ocfg, 100)) - 0.1) < 1e-5

    def test_clipping(self):
        ocfg = OptimizerConfig(clip_norm=1.0)
        p = {"w": jnp.ones((4, 4))}
        g = {"w": jnp.full((4, 4), 100.0)}
        st = opt.init(p)
        p2, st2, m = opt.update(ocfg, p, g, st)
        assert float(m["grad_norm"]) > 1.0
        # post-clip update magnitude bounded by lr * O(1)
        assert float(jnp.abs(p2["w"] - p["w"]).max()) < 10 * ocfg.lr

    def test_decay_only_on_matrices(self):
        ocfg = OptimizerConfig(lr=1e-2, weight_decay=1.0, warmup_steps=0)
        p = {"w": jnp.ones((4, 4)), "b": jnp.ones((4,))}
        g = {"w": jnp.zeros((4, 4)), "b": jnp.zeros((4,))}
        st = opt.init(p)
        p2, _, _ = opt.update(ocfg, p, g, st)
        assert float(p2["w"][0, 0]) < 1.0        # decayed
        assert float(p2["b"][0]) == 1.0          # not decayed


class TestTraining:
    def test_loss_decreases(self):
        cfg, data, params, ocfg, step_fn = tiny_setup(steps=100)
        ostate = opt.init(params)
        losses = []
        for s in range(100):
            b = {k: jnp.asarray(v) for k, v in data.batch_at(s).items()}
            params, ostate, m = step_fn(params, ostate, b)
            losses.append(float(m["loss"]))
        first = np.mean(losses[:5])
        last = np.mean(losses[-5:])
        assert last < first - 1.0, (first, last)

    def test_determinism(self):
        cfg, data, params, ocfg, step_fn = tiny_setup()
        ostate = opt.init(params)
        b = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}
        p1, o1, m1 = step_fn(params, ostate, b)
        p2, o2, m2 = step_fn(params, ostate, b)
        assert float(m1["loss"]) == float(m2["loss"])


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = {"a": jnp.arange(6).reshape(2, 3),
                "nested": {"b": jnp.ones((4,), jnp.bfloat16)}}
        ckpt.save(str(tmp_path), 7, tree)
        out, step, _ = ckpt.restore(str(tmp_path), tree)
        assert step == 7
        np.testing.assert_array_equal(np.asarray(out["a"]),
                                      np.asarray(tree["a"]))
        assert out["nested"]["b"].dtype == jnp.bfloat16

    def test_namedtuple_roundtrip(self, tmp_path):
        state = opt.init({"w": jnp.ones((3, 3))})
        ckpt.save(str(tmp_path), 1, state)
        out, _, _ = ckpt.restore(str(tmp_path), state)
        assert isinstance(out, opt.OptState)
        np.testing.assert_array_equal(out.step, state.step)

    def test_latest_and_gc(self, tmp_path):
        tree = {"x": jnp.zeros(2)}
        ac = ckpt.AsyncCheckpointer(str(tmp_path), keep=2)
        for s in (1, 2, 3, 4):
            ac.save(s, tree)
        ac.wait()
        assert ckpt.all_steps(str(tmp_path)) == [3, 4]
        assert ckpt.latest_step(str(tmp_path)) == 4

    def test_restart_equivalence(self, tmp_path):
        """Train 10; vs train 5, 'crash', resume, train 5 — same params."""
        cfg, data, params0, ocfg, step_fn = tiny_setup()

        def train(params, ostate, a, b):
            for s in range(a, b):
                bt = {k: jnp.asarray(v) for k, v in data.batch_at(s).items()}
                params, ostate, _ = step_fn(params, ostate, bt)
            return params, ostate

        # uninterrupted
        pA, oA = train(params0, opt.init(params0), 0, 10)
        # interrupted at 5 + resume from checkpoint
        p5, o5 = train(params0, opt.init(params0), 0, 5)
        ckpt.save(str(tmp_path), 5, (p5, o5))
        (pR, oR), step, _ = ckpt.restore(str(tmp_path), (p5, o5))
        pB, oB = train(pR, oR, 5, 10)
        for a, b in zip(jax.tree.leaves(pA), jax.tree.leaves(pB)):
            np.testing.assert_allclose(
                np.asarray(a, np.float32), np.asarray(b, np.float32),
                rtol=1e-6, atol=1e-6,
            )


class TestRunner:
    def test_runner_end_to_end_with_resume(self, tmp_path):
        cfg, data, params, ocfg, step_fn = tiny_setup()
        rcfg = RunnerConfig(ckpt_dir=str(tmp_path), ckpt_every=5,
                            max_steps=8, log_every=100)

        def batches(start=0):
            s = start
            while True:
                yield {k: jnp.asarray(v) for k, v in data.batch_at(s).items()}
                s += 1

        r1 = TrainRunner(rcfg, step_fn, params, opt.init(params),
                         log=lambda s: None)
        out1 = r1.run(batches())
        assert out1["final_step"] == 8
        # "crash": new runner resumes from the final checkpoint
        r2 = TrainRunner(
            rcfg._replace_max(16) if hasattr(rcfg, "_replace_max")
            else RunnerConfig(ckpt_dir=str(tmp_path), ckpt_every=5,
                              max_steps=16, log_every=100),
            step_fn, params, opt.init(params), log=lambda s: None,
        )
        assert r2.step == 8                       # resumed
        out2 = r2.run(batches(8))
        assert out2["final_step"] == 16


class TestGradCompression:
    def test_quantize_roundtrip_error_bounded(self):
        from repro.train import grad_compress as gc
        g = jax.random.normal(jax.random.PRNGKey(0), (128,))
        q, s = gc.quantize(g)
        err = np.abs(np.asarray(gc.dequantize(q, s) - g)).max()
        assert err <= float(s) / 2 + 1e-7

    def test_error_feedback_converges(self):
        """Mean of compressed grads ≈ mean of true grads over time."""
        from repro.train import grad_compress as gc
        rng = np.random.default_rng(0)
        true_sum = np.zeros(64)
        comp_sum = np.zeros(64)
        state = gc.init({"g": jnp.zeros(64)})
        for _ in range(200):
            g = {"g": jnp.asarray(rng.normal(0, 1, 64), jnp.float32)}
            q, s, state = gc.compress_tree(g, state)
            true_sum += np.asarray(g["g"])
            comp_sum += np.asarray(gc.dequantize(q["g"], s["g"]))
        # error feedback keeps the running sums together
        assert np.abs(true_sum - comp_sum).max() < 1.0

    def test_psum_compressed_multidevice(self):
        from util_subproc import run_with_devices
        code = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, AxisType
from jax import shard_map
from repro.train import grad_compress as gc

mesh = jax.make_mesh((4,), ("pod",), axis_types=(AxisType.Auto,))
g = jax.random.normal(jax.random.PRNGKey(0), (4, 64))

def f(gl):
    grads = {"g": gl[0]}
    state = gc.init(grads)
    out, _ = gc.psum_compressed(grads, state, "pod")
    return out["g"][None]

got = shard_map(f, mesh=mesh, in_specs=P("pod", None),
                out_specs=P("pod", None))(g)
want = np.asarray(g).sum(0)
err = np.abs(np.asarray(got)[0] - want).max()
rel = err / (np.abs(want).max() + 1e-9)
assert rel < 0.05, (err, rel)
print("compressed psum ok", rel)
"""
        run_with_devices(code, 4)


class TestDataPipeline:
    def test_determinism_and_seekability(self):
        from repro.data import DataConfig, SyntheticLM
        import numpy as np
        d1 = SyntheticLM(DataConfig(vocab=512, seq_len=32, global_batch=8))
        d2 = SyntheticLM(DataConfig(vocab=512, seq_len=32, global_batch=8))
        b1 = d1.batch_at(17)
        b2 = d2.batch_at(17)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        # host sharding partitions the global batch
        dA = SyntheticLM(DataConfig(vocab=512, seq_len=32, global_batch=8,
                                    n_host=2, host_id=0))
        dB = SyntheticLM(DataConfig(vocab=512, seq_len=32, global_batch=8,
                                    n_host=2, host_id=1))
        a = dA.batch_at(3)["tokens"]
        b = dB.batch_at(3)["tokens"]
        full = d1.batch_at(3)["tokens"]
        np.testing.assert_array_equal(a, full[0::2])
        np.testing.assert_array_equal(b, full[1::2])

    def test_labels_are_shifted_tokens(self):
        from repro.data import DataConfig, SyntheticLM
        import numpy as np
        d = SyntheticLM(DataConfig(vocab=512, seq_len=16, global_batch=2))
        b = d.batch_at(0)
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])
