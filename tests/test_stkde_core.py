"""Core STKDE algorithm tests: equivalence, properties, geometry."""
import math

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    Domain,
    vb,
    vb_dec,
    pb,
    clustered_events,
    bucketing,
    kernels_math as km,
)
from repro.core.geometry import from_points


def small_domain(hs=3.0, ht=2.0):
    return Domain(gx=24.0, gy=18.0, gt=14.0, sres=1.0, tres=1.0, hs=hs, ht=ht)


# --------------------------------------------------------------- equivalence
class TestEquivalence:
    def test_all_variants_match_vb(self):
        dom = small_domain()
        pts = clustered_events(300, dom, seed=0)
        gold = np.asarray(vb(jnp.asarray(pts), dom))
        for variant in ("pb", "disk", "bar", "sym"):
            got = np.asarray(pb(pts, dom, variant=variant))
            np.testing.assert_allclose(got, gold, rtol=1e-5, atol=1e-8)

    def test_vb_dec_matches_vb(self):
        dom = small_domain()
        pts = clustered_events(300, dom, seed=1)
        np.testing.assert_allclose(
            np.asarray(vb_dec(pts, dom)),
            np.asarray(vb(jnp.asarray(pts), dom)),
            rtol=1e-5,
            atol=1e-8,
        )

    @settings(max_examples=12, deadline=None)
    @given(
        hs=st.floats(0.6, 4.5),
        ht=st.floats(0.6, 3.5),
        sres=st.floats(0.5, 1.5),
        tres=st.floats(0.5, 1.5),
        n=st.integers(5, 120),
        seed=st.integers(0, 10_000),
    )
    def test_property_pb_equals_vb(self, hs, ht, sres, tres, n, seed):
        dom = Domain(
            gx=16.0, gy=12.0, gt=10.0, sres=sres, tres=tres, hs=hs, ht=ht
        )
        pts = clustered_events(n, dom, seed=seed)
        gold = np.asarray(vb(jnp.asarray(pts), dom))
        got = np.asarray(pb(pts, dom))
        np.testing.assert_allclose(got, gold, rtol=1e-4, atol=1e-7)

    def test_paper_verbatim_kernels_also_equivalent(self):
        dom = small_domain()
        pts = clustered_events(100, dom, seed=2)
        kw = dict(ks=km.ks_paper_verbatim, kt=km.kt_paper_verbatim)
        gold = np.asarray(vb(jnp.asarray(pts), dom, **kw))
        got = np.asarray(pb(pts, dom, variant="sym", **kw))
        np.testing.assert_allclose(got, gold, rtol=1e-5, atol=1e-8)


# ---------------------------------------------------------------- properties
class TestProperties:
    def test_mass(self):
        """Total mass ~ 2/3 for interior points (kernel integral; DESIGN §6)."""
        dom = Domain(
            gx=40.0, gy=40.0, gt=40.0, sres=0.25, tres=0.25, hs=4.0, ht=4.0
        )
        rng = np.random.default_rng(0)
        pts = (10 + 20 * rng.random((50, 3))).astype(np.float32)  # interior
        grid = np.asarray(pb(pts, dom))
        mass = grid.sum() * dom.sres**2 * dom.tres
        assert abs(mass - 2.0 / 3.0) < 0.02, mass

    def test_nonnegative_and_finite(self):
        dom = small_domain()
        pts = clustered_events(500, dom, seed=3)
        grid = np.asarray(pb(pts, dom))
        assert np.isfinite(grid).all()
        assert (grid >= 0).all()

    def test_translation_invariance(self):
        """Shifting points and origin by whole voxels shifts the grid."""
        dom = small_domain()
        pts = clustered_events(80, dom, seed=4)
        g0 = np.asarray(pb(pts, dom))
        import dataclasses

        dom2 = dataclasses.replace(dom, ox=dom.ox + 5.0)  # +5 voxels in x
        g1 = np.asarray(pb(pts + np.array([5.0, 0, 0], np.float32), dom2))
        np.testing.assert_allclose(g1, g0, rtol=1e-5, atol=1e-8)

    def test_single_point_peak_location(self):
        dom = small_domain()
        pts = np.array([[12.5, 9.5, 7.5]], dtype=np.float32)
        grid = np.asarray(pb(pts, dom))
        assert np.unravel_index(grid.argmax(), grid.shape) == (12, 9, 7)

    def test_boundary_points_no_crash_no_nan(self):
        dom = small_domain()
        pts = np.array(
            [[0.01, 0.01, 0.01], [23.9, 17.9, 13.9], [0.0, 17.99, 7.0]],
            dtype=np.float32,
        )
        for variant in ("pb", "sym"):
            grid = np.asarray(pb(pts, dom, variant=variant))
            assert np.isfinite(grid).all()
            # boundary points lose part of their cylinder -> less mass
            assert grid.sum() > 0

    def test_superposition(self):
        """Density is a sum over points (linearity in the point set)."""
        dom = small_domain()
        pts = clustered_events(40, dom, seed=5)
        g_all = np.asarray(pb(pts, dom)) * len(pts)
        g_sum = sum(
            np.asarray(pb(pts[i : i + 1], dom)) for i in range(len(pts))
        )
        np.testing.assert_allclose(g_all, g_sum, rtol=1e-4, atol=1e-7)


# ------------------------------------------------------------------ geometry
class TestGeometry:
    def test_grid_shape_ceil(self):
        dom = Domain(gx=10.1, gy=8.0, gt=3.5, sres=1.0, tres=1.0, hs=2, ht=1)
        assert dom.grid_shape == (11, 8, 4)
        assert dom.Hs == 2 and dom.Ht == 1

    def test_from_points_contains_all(self):
        rng = np.random.default_rng(1)
        pts = rng.normal(100, 30, size=(200, 3)).astype(np.float32)
        dom = from_points(pts, sres=2.0, tres=3.0, hs=5.0, ht=6.0)
        vox = np.asarray(dom.point_voxels(jnp.asarray(pts)))
        assert (vox >= 0).all()
        assert (vox < np.array(dom.grid_shape)).all()

    @settings(max_examples=20, deadline=None)
    @given(
        sres=st.floats(0.3, 3.0),
        hs=st.floats(0.5, 6.0),
    )
    def test_voxel_bandwidth_covers_kernel_support(self, sres, hs):
        """Hs*sres >= hs: the voxel cylinder bbox covers the true support."""
        dom = Domain(gx=10, gy=10, gt=10, sres=sres, tres=1.0, hs=hs, ht=1.0)
        assert dom.Hs * sres >= hs - 1e-6


# ----------------------------------------------------------------- bucketing
class TestBucketing:
    def test_home_counts_sum_to_n(self):
        dom = small_domain()
        pts = clustered_events(500, dom, seed=6)
        b = bucketing.bucket_points_home(pts, dom, (8, 8, 8))
        assert b.counts.sum() == 500
        assert b.valid.sum() == 500

    def test_overlap_superset_of_home(self):
        dom = small_domain()
        pts = clustered_events(200, dom, seed=7)
        bh = bucketing.bucket_points_home(pts, dom, (8, 8, 8))
        bo = bucketing.bucket_points_overlap(pts, dom, (8, 8, 8))
        assert bo.counts.sum() >= bh.counts.sum()
        assert bo.replication_factor >= 1.0

    def test_overlap_covers_every_affected_tile(self):
        """A point's kernel support never leaks outside its overlap tiles."""
        dom = small_domain(hs=3.0, ht=2.0)
        pts = np.array([[11.7, 8.2, 6.9]], dtype=np.float32)
        tile = (8, 8, 4)
        b = bucketing.bucket_points_overlap(pts, dom, tile)
        g_full = np.asarray(pb(pts, dom))
        covered = np.zeros(dom.grid_shape, dtype=bool)
        ntx, nty, ntt = b.ntiles
        for i in range(ntx):
            for j in range(nty):
                for k in range(ntt):
                    if b.counts[i, j, k]:
                        covered[
                            i * tile[0] : (i + 1) * tile[0],
                            j * tile[1] : (j + 1) * tile[1],
                            k * tile[2] : (k + 1) * tile[2],
                        ] = True
        assert (g_full[~covered] == 0).all()

    def test_capacity_overflow_raises(self):
        dom = small_domain()
        pts = clustered_events(100, dom, seed=8)
        with pytest.raises(ValueError):
            bucketing.bucket_points_home(pts, dom, (8, 8, 8), cap=1)
