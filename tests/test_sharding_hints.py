"""Sharding-hint machinery: no-op without a mesh, correct placement with."""
import textwrap

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed import sharding
from util_subproc import run_with_devices


def test_hint_is_noop_without_mesh():
    x = jnp.ones((4, 8))
    y = sharding.hint(x, "batch", "model")
    assert y is x  # literally untouched


def test_hint_applies_under_mesh():
    code = textwrap.dedent("""
    import jax, jax.numpy as jnp
    from jax.sharding import AxisType
    from repro.distributed import sharding

    mesh = jax.make_mesh((4, 2), ("data", "model"),
                         axis_types=(AxisType.Auto,)*2)

    def f(x):
        with sharding.hint_mesh(mesh):
            return sharding.hint(x * 2, "batch", "model")

    x = jnp.ones((8, 4))
    out = jax.jit(f)(x)
    ns = out.sharding
    P = jax.sharding.PartitionSpec
    # newer JAX normalizes the singleton axis tuple; accept both spellings
    assert ns.spec in (P(("data",), "model"), P("data", "model")), ns.spec
    print("hint spec ok", ns.spec)
    """)
    run_with_devices(code, 8)


def test_hint_drops_nondivisible_axes():
    code = textwrap.dedent("""
    import jax, jax.numpy as jnp
    from jax.sharding import AxisType
    from repro.distributed import sharding

    mesh = jax.make_mesh((4, 2), ("data", "model"),
                         axis_types=(AxisType.Auto,)*2)

    def f(x):
        with sharding.hint_mesh(mesh):
            # dim0=3 not divisible by 4 -> dropped; dim1=4 divisible by 2
            return sharding.hint(x + 1, "batch", "model")

    out = jax.jit(f)(jnp.ones((3, 4)))
    assert out.sharding.spec == jax.sharding.PartitionSpec(None, "model")
    print("nondivisible dropped ok")
    """)
    run_with_devices(code, 8)


def test_decode_consistency_with_hints_active():
    """Hints must not change decode numerics (only placement)."""
    code = textwrap.dedent("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import AxisType
    from repro.configs import ARCHS, reduced
    from repro.distributed import sharding
    from repro.models import init_params, prefill, decode_step

    cfg = reduced(ARCHS["mistral-nemo-12b"])
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (8, 8), 0, cfg.vocab)
    lg, st = prefill(cfg, params, toks, max_seq=32)
    ref, _ = decode_step(cfg, params, toks[:, :1], st)

    mesh = jax.make_mesh((4, 2), ("data", "model"),
                         axis_types=(AxisType.Auto,)*2)

    def f(p, s, t):
        with sharding.hint_mesh(mesh):
            return decode_step(cfg, p, t, s)

    got, _ = jax.jit(f)(params, st, toks[:, :1])
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)
    print("hinted decode == unhinted decode")
    """)
    run_with_devices(code, 8)
