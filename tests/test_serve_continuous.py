"""Continuous-batching (slot-swap) serving tests.

Covers the PR-8 acceptance criteria: greedy slot-swap output is
token-identical to the bucketed reference oracle on mixed-length prompts
with staggered EOS, per-slot deadline truncation, chaos (injected
`serve.prefill`/`serve.decode` faults) still yields a terminal
``RequestResult`` for every admitted uid, queue wait is observed exactly
once per request even when retries fire, and sampling is a pure function
of (seed, uid, position) so fault history cannot shift served tokens.
"""
import os

import jax
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.models import init_params
from repro.obs import metrics
from repro.resilience import ReproValidationError, RetryPolicy, faults
from repro.serve import EngineConfig, ServingEngine

# chaos spec for the env-driven tests; the CI serve job's chaos matrix
# overrides via REPRO_FAULTS
CHAOS_SPEC = os.environ.get(
    "REPRO_FAULTS", "serve.prefill:oom:0.15,serve.decode:nan:0.10")
CHAOS_SEED = int(os.environ.get("REPRO_FAULTS_SEED", "42"))


@pytest.fixture(autouse=True)
def _explicit_faults_only():
    """These tests drive the injector explicitly (exact-token asserts);
    neutralize any ambient REPRO_FAULTS — chaos tests opt back in by
    calling ``faults.configure(CHAOS_SPEC, ...)`` themselves."""
    faults.configure("", 0)
    yield


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(ARCHS["smollm-360m"])
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _mixed_workload(cfg, n=8, seed=0):
    rng = np.random.default_rng(seed)
    lens = [8, 12, 8, 16, 12, 9, 8, 16][:n]
    return [(uid, rng.integers(0, cfg.vocab, L), 3 + (uid % 3) * 3)
            for uid, L in enumerate(lens)]


def _run(cfg, params, workload, **ekw):
    eng = ServingEngine(cfg, params, EngineConfig(
        max_batch=4, max_seq=64, **ekw))
    for uid, prompt, max_new in workload:
        eng.submit(uid, prompt, max_new=max_new)
    return eng, eng.run_detailed()


# ----------------------------------------------------- oracle equivalence
def test_greedy_matches_bucketed_oracle(setup):
    """Slot-swap greedy decode is token-identical to the bucketed path on
    mixed-length prompts with varied max_new."""
    cfg, params = setup
    wl = _mixed_workload(cfg)
    _, ref = _run(cfg, params, wl, continuous_batching=False)
    _, got = _run(cfg, params, wl, continuous_batching=True)
    assert set(got) == set(ref)
    for uid in ref:
        assert got[uid].tokens.tolist() == ref[uid].tokens.tolist(), uid
        assert got[uid].ok and ref[uid].ok


def test_greedy_matches_oracle_with_staggered_eos(setup):
    """Rows hitting EOS at different depths swap out early; outputs must
    still match the bucketed reference exactly."""
    cfg, params = setup
    wl = _mixed_workload(cfg)
    _, free = _run(cfg, params, wl, continuous_batching=True)
    # pick a token that actually occurs mid-stream so stops stagger
    counts = {}
    for r in free.values():
        for t in r.tokens.tolist()[1:]:
            counts[t] = counts.get(t, 0) + 1
    eos = max(counts, key=counts.get)
    _, ref = _run(cfg, params, wl, continuous_batching=False, eos_id=eos)
    _, got = _run(cfg, params, wl, continuous_batching=True, eos_id=eos)
    lengths = set()
    for uid in ref:
        assert got[uid].tokens.tolist() == ref[uid].tokens.tolist(), uid
        lengths.add(len(got[uid].tokens))
    assert len(lengths) > 1, "EOS stops did not stagger"


@pytest.mark.parametrize("arch", ["deepseek-v2-lite-16b", "rwkv6-3b"])
def test_greedy_matches_oracle_other_mixers(arch):
    """Per-row cursors hold for MLA latent caches and recurrent state."""
    cfg = reduced(ARCHS[arch])
    params = init_params(cfg, jax.random.PRNGKey(0))
    wl = _mixed_workload(cfg, n=5)
    _, ref = _run(cfg, params, wl, continuous_batching=False)
    _, got = _run(cfg, params, wl, continuous_batching=True)
    for uid in ref:
        assert got[uid].tokens.tolist() == ref[uid].tokens.tolist(), uid


def test_enc_dec_falls_back_to_bucketed():
    """Slot-swap has no per-row encoder-output scatter; whisper-style
    configs must transparently use the bucketed reference path."""
    cfg = reduced(ARCHS["whisper-large-v3"])
    eng = ServingEngine(cfg, None, EngineConfig(max_batch=2, max_seq=32))
    assert not eng._continuous


# ------------------------------------------------------ per-slot deadline
def test_per_slot_deadline_truncates(setup):
    cfg, params = setup
    eng, res = _run(cfg, params, [(0, np.arange(8), 16)],
                    continuous_batching=True, request_timeout_s=1e-6)
    assert res[0].ok and res[0].degraded
    assert res[0].reason == "deadline_truncated"
    assert 1 <= len(res[0].tokens) < 16


def test_timeout_zero_means_expire_now(setup):
    """request_timeout_s=0 is a real (immediate) deadline, not 'disabled'
    — the old falsy check silently dropped it."""
    cfg, params = setup
    _, res = _run(cfg, params, [(0, np.arange(8), 16)],
                  continuous_batching=True, request_timeout_s=0.0)
    assert res[0].degraded and res[0].reason == "deadline_truncated"
    assert len(res[0].tokens) < 16


def test_negative_timeout_rejected(setup):
    cfg, params = setup
    with pytest.raises(ReproValidationError):
        ServingEngine(cfg, params,
                      EngineConfig(max_seq=64, request_timeout_s=-0.5))


# ----------------------------------------------------------------- chaos
def test_chaos_every_uid_terminal_and_deterministic(setup):
    """Injected prefill/decode faults: every admitted uid ends in a
    terminal RequestResult, and a fresh engine + freshly seeded injector
    replays the identical outcome."""
    cfg, params = setup

    def chaos_run():
        faults.configure(CHAOS_SPEC, seed=CHAOS_SEED)
        eng, res = _run(cfg, params, _mixed_workload(cfg),
                        continuous_batching=True, max_queue=32)
        return res

    res = chaos_run()
    assert set(res) == set(range(8))
    for r in res.values():
        assert r.ok or (r.degraded and r.reason), r
        assert isinstance(r.tokens, np.ndarray)
    res2 = chaos_run()
    assert {u: (r.ok, r.degraded, r.tokens.tolist())
            for u, r in res.items()} == \
           {u: (r.ok, r.degraded, r.tokens.tolist())
            for u, r in res2.items()}


def test_poisoned_decode_fails_per_slot_not_engine(setup):
    """A 100% decode-NaN site: every request still terminates with a
    typed failure and the scheduler itself never raises."""
    cfg, params = setup
    faults.configure("serve.decode:nan:1.0", seed=0)
    eng, res = _run(cfg, params, _mixed_workload(cfg, n=5),
                    continuous_batching=True,
                    retry=RetryPolicy(max_attempts=2, base_delay_s=0.001))
    assert set(res) == set(range(5))
    for r in res.values():
        assert not r.ok and r.degraded
        assert "NonFinite" in r.reason or "Retries" in r.reason
    assert metrics.export()["counters"]["serve.failed"] == 5


# --------------------------------------------------------------- metrics
@pytest.mark.parametrize("continuous", [True, False])
def test_queue_wait_observed_once_per_request(setup, continuous):
    """Retried work must not re-observe serve.queue_wait_s — one sample
    per request, taken at the first service attempt."""
    cfg, params = setup
    faults.configure("serve.prefill:oom:0.5", seed=3)
    wl = _mixed_workload(cfg, n=6)
    _, res = _run(cfg, params, wl, continuous_batching=continuous)
    exported = metrics.export()
    assert exported["histograms"]["serve.queue_wait_s"]["count"] == len(wl)
    # the spec above does force retries, so the old per-attempt
    # observation would have counted > len(wl)
    assert exported["counters"].get(
        "resilience.retries.serve.prefill" if continuous
        else "resilience.retries.serve.bucket", 0) >= 1
    assert set(res) == {uid for uid, _, _ in wl}


def test_swap_and_occupancy_metrics(setup):
    cfg, params = setup
    wl = _mixed_workload(cfg)
    eng, res = _run(cfg, params, wl, continuous_batching=True)
    exported = metrics.export()
    assert exported["histograms"]["serve.swap_s"]["count"] == len(wl)
    assert 0.0 <= exported["gauges"]["serve.slot_occupancy"] <= 1.0
    assert "serve.slot_idle_frac" in exported["gauges"]
    st = eng.last_stats
    assert st["mode"] == "continuous"
    assert st["swaps"] == len(wl)
    assert 0 < st["active_slot_steps"] <= st["slot_steps"]
    assert st["n_tokens"] == sum(len(r.tokens) for r in res.values())


# ------------------------------------------------- sampling determinism
def test_sampling_independent_of_fault_history(setup):
    """Per-request fold_in(base_key, uid) keys: a retried/fault-ridden run
    serves the same tokens as a clean run for every request that
    completes — the engine-level RNG stream is gone."""
    cfg, params = setup
    wl = _mixed_workload(cfg, n=6)

    def run(spec):
        faults.configure(spec, seed=7)
        _, res = _run(cfg, params, wl, continuous_batching=True,
                      temperature=1.0, seed=5)
        return {u: (r.ok, r.tokens.tolist()) for u, r in res.items()}

    clean = run("")
    chaotic = run("serve.prefill:oom:0.3,serve.decode:oom:0.2")
    # the chaos run must actually have exercised the retry machinery
    assert (metrics.export()["counters"].get("resilience.retries", 0) >= 1
            or any(not ok for ok, _ in chaotic.values()))
    for uid, (ok, toks) in chaotic.items():
        if ok:
            assert toks == clean[uid][1], uid


def test_sampled_stream_matches_bucketed(setup):
    """Both scheduling modes draw from the same (seed, uid, position)
    keys, so even temperature sampling is schedule-invariant."""
    cfg, params = setup
    wl = _mixed_workload(cfg, n=6)
    _, ref = _run(cfg, params, wl, continuous_batching=False,
                  temperature=1.0, seed=3)
    _, got = _run(cfg, params, wl, continuous_batching=True,
                  temperature=1.0, seed=3)
    for uid in ref:
        assert got[uid].tokens.tolist() == ref[uid].tokens.tolist(), uid
