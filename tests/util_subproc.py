"""Run a python snippet in a subprocess with N fake XLA host devices.

Used by multi-device tests so the main pytest process keeps seeing exactly
one CPU device (required by the smoke tests and benchmarks).
"""
from __future__ import annotations

import os
import pathlib
import subprocess
import sys

SRC = str(pathlib.Path(__file__).resolve().parent.parent / "src")


def run_with_devices(code: str, n_devices: int = 8, timeout: int = 600) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={n_devices} "
        + env.get("XLA_FLAGS", "")
    )
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    # JAX version shims (jax.shard_map, AxisType, ...) must be installed
    # before snippets import those names straight from jax.
    code = "import repro.compat\n" + code
    proc = subprocess.run(
        [sys.executable, "-c", code],
        env=env,
        capture_output=True,
        text=True,
        timeout=timeout,
    )
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed (rc={proc.returncode})\n"
            f"--- stdout ---\n{proc.stdout}\n--- stderr ---\n{proc.stderr}"
        )
    return proc.stdout


def popen_with_devices(code: str, n_devices: int = 8,
                       clean_faults: bool = True) -> subprocess.Popen:
    """Launch the snippet without waiting — for kill/crash tests.

    Same environment setup as ``run_with_devices`` but returns the live
    ``subprocess.Popen`` so the caller can SIGKILL it mid-run and inspect
    the on-disk state it left behind. ``clean_faults`` strips any ambient
    ``REPRO_FAULTS`` so determinism tests control injection explicitly.
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={n_devices} "
        + env.get("XLA_FLAGS", "")
    )
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    if clean_faults:
        env.pop("REPRO_FAULTS", None)
    code = "import repro.compat\n" + code
    return subprocess.Popen(
        [sys.executable, "-c", code],
        env=env,
        stdout=subprocess.PIPE,
        stderr=subprocess.PIPE,
        text=True,
    )
