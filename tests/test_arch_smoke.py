"""Per-architecture smoke tests: reduced same-family config, one forward +
one train step on CPU, asserting shapes and finiteness (no NaNs)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.models import (
    init_params, forward, init_decode_state, decode_step, prefill,
)

ALL = sorted(ARCHS)


def _batch(cfg, B=2, S=16):
    key = jax.random.PRNGKey(7)
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    kw = {}
    if cfg.frontend == "vision":
        kw["vision_embeds"] = (
            jax.random.normal(key, (B, cfg.n_vision_tokens, cfg.d_model))
            * 0.02
        )
    if cfg.enc_dec:
        kw["audio_frames"] = (
            jax.random.normal(key, (B, cfg.enc_seq, cfg.d_model)) * 0.02
        )
    return toks, kw


@pytest.mark.parametrize("arch", ALL)
def test_forward_smoke(arch):
    cfg = reduced(ARCHS[arch])
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks, kw = _batch(cfg)
    logits, aux = jax.jit(
        lambda p, t: forward(cfg, p, t, **kw)
    )(params, toks)
    S_extra = cfg.n_vision_tokens if cfg.frontend == "vision" else 0
    assert logits.shape == (2, 16 + S_extra, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ALL)
def test_train_step_smoke(arch):
    """One loss+grad step must produce finite loss and finite grads."""
    cfg = reduced(ARCHS[arch])
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks, kw = _batch(cfg)
    labels = jnp.roll(toks, -1, axis=1)

    def loss_fn(p):
        logits, aux = forward(cfg, p, toks, **kw)
        logits = logits[:, -toks.shape[1]:]          # text positions only
        lp = jax.nn.log_softmax(logits, -1)
        nll = -jnp.take_along_axis(lp, labels[..., None], -1).mean()
        return nll + aux

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert np.isfinite(float(loss)), f"{arch}: loss {loss}"
    leaves = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(g)).all() for g in leaves), arch
    # at least most grads should be nonzero
    nonzero = sum(float(np.abs(np.asarray(g)).sum()) > 0 for g in leaves)
    assert nonzero > len(leaves) * 0.5, f"{arch}: too many zero grads"


@pytest.mark.parametrize("arch", ALL)
def test_decode_smoke(arch):
    """Prefill + 3 decode steps: finite logits, state advances."""
    cfg = reduced(ARCHS[arch])
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks, kw = _batch(cfg, S=8)
    lg, state = jax.jit(
        lambda p, t: prefill(cfg, p, t, max_seq=32, **kw)
    )(params, toks)
    assert np.isfinite(np.asarray(lg)).all()
    step = jax.jit(lambda p, t, s: decode_step(cfg, p, t, s))
    tok = jnp.argmax(lg[:, -1], -1)[:, None]
    for _ in range(3):
        lg2, state = step(params, tok, state)
        assert np.isfinite(np.asarray(lg2)).all()
        tok = jnp.argmax(lg2[:, -1], -1)[:, None]
    assert int(state.step) == (cfg.n_vision_tokens if cfg.frontend ==
                               "vision" else 0) + 8 + 3


@pytest.mark.parametrize("arch", ALL)
def test_decode_matches_forward(arch):
    """Teacher-forced decode logits == forward logits (tight check)."""
    cfg = reduced(ARCHS[arch])
    if cfg.frontend == "vision":
        pytest.skip("vlm prefill covers the image prefix; checked above")
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks, kw = _batch(cfg, S=8)
    logits, _ = forward(cfg, params, toks, **kw)
    _, state = prefill(cfg, params, toks[:, :4], max_seq=16, **kw)
    errs = []
    st = state
    for t in range(4, 8):
        lg, st = decode_step(cfg, params, toks[:, t : t + 1], st)
        errs.append(float(np.abs(np.asarray(lg[:, 0] - logits[:, t])).max()))
    assert max(errs) < 5e-3, f"{arch}: decode drift {errs}"
