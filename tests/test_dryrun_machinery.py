"""Dry-run machinery tests on a small fake mesh (subprocess).

Validates the same lower->compile->analyze pipeline the production dry-run
uses, at 8 devices with reduced configs — fast enough for CI, and catching
sharding-rule regressions before the expensive 512-device runs.
"""
import textwrap

import numpy as np
import pytest

from util_subproc import run_with_devices


def test_sharding_rules_cover_all_archs():
    """Every param leaf gets a valid spec; sharded axes divide dims."""
    code = textwrap.dedent("""
    import jax, numpy as np
    from jax.sharding import AxisType
    from repro.configs import ARCHS, reduced
    from repro.distributed import sharding
    from repro.launch.specs import param_specs_abstract

    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                         axis_types=(AxisType.Auto,)*3)
    for name, full in ARCHS.items():
        cfg = reduced(full)
        abs_p = param_specs_abstract(cfg)
        specs = sharding.param_specs(abs_p, mesh, fsdp=True)
        flat_p = jax.tree.leaves(abs_p)
        flat_s = jax.tree.leaves(
            specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
        assert len(flat_p) == len(flat_s), name
        for arr, spec in zip(flat_p, flat_s):
            for i, ax in enumerate(spec):
                if ax is None:
                    continue
                size = np.prod([mesh.shape[a] for a in (
                    ax if isinstance(ax, tuple) else (ax,))])
                assert arr.shape[i] % size == 0, (name, arr.shape, spec)
        print(name, "ok")
    """)
    run_with_devices(code, 8)


def test_train_cell_lowers_and_is_numerically_correct():
    """Sharded train step == single-device train step (tiny config)."""
    code = textwrap.dedent("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import AxisType
    from repro.configs import ARCHS, reduced
    from repro.distributed import sharding
    from repro.models import init_params
    from repro.train import OptimizerConfig, make_train_step, optimizer as opt

    cfg = reduced(ARCHS["mistral-nemo-12b"])
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                         axis_types=(AxisType.Auto,)*3)
    params = init_params(cfg, jax.random.PRNGKey(0))
    ostate = opt.init(params)
    toks = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
    step = make_train_step(cfg, OptimizerConfig(lr=1e-3))

    # reference: plain jit on default device placement
    p_ref, o_ref, m_ref = jax.jit(step)(params, ostate, batch)

    p_specs = sharding.param_specs(params, mesh, fsdp=True)
    o_specs = opt.OptState(mu=p_specs, nu=p_specs,
                           step=jax.sharding.PartitionSpec())
    b_specs = sharding.data_specs(batch, mesh)
    fn = jax.jit(step, in_shardings=(
        sharding.make_sharding(p_specs, mesh),
        sharding.make_sharding(o_specs, mesh),
        sharding.make_sharding(b_specs, mesh),
    ))
    ps = jax.device_put(params, sharding.make_sharding(p_specs, mesh))
    os_ = jax.device_put(ostate, sharding.make_sharding(o_specs, mesh))
    bs = jax.device_put(batch, sharding.make_sharding(b_specs, mesh))
    p_sh, o_sh, m_sh = fn(ps, os_, bs)
    assert abs(float(m_ref["loss"]) - float(m_sh["loss"])) < 1e-3, (
        float(m_ref["loss"]), float(m_sh["loss"]))
    for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_sh)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-3, atol=2e-3)
    print("sharded == unsharded train step ok", float(m_sh["loss"]))
    """)
    run_with_devices(code, 8)


def test_decode_cell_lowers_on_small_mesh():
    code = textwrap.dedent("""
    import jax, jax.numpy as jnp
    from jax.sharding import AxisType
    from repro.configs import ARCHS, reduced
    from repro.distributed import sharding
    from repro.launch import specs as specs_lib
    from repro.models import model as model_lib

    mesh = jax.make_mesh((4, 2), ("data", "model"),
                         axis_types=(AxisType.Auto,)*2)
    for arch in ("mistral-nemo-12b", "rwkv6-3b", "zamba2-7b",
                 "deepseek-v2-lite-16b"):
        cfg = reduced(ARCHS[arch])
        params_abs = specs_lib.param_specs_abstract(cfg)
        state = jax.eval_shape(
            lambda: model_lib.init_decode_state(cfg, 8, 64, jnp.float32))
        token = jax.ShapeDtypeStruct((8, 1), jnp.int32)
        p_specs = sharding.param_specs(params_abs, mesh, fsdp=False)
        s_specs = sharding.decode_state_specs(cfg, state, mesh)

        def fn(params, st, tok):
            return model_lib.decode_step(cfg, params, tok, st)

        jitted = jax.jit(fn, in_shardings=(
            sharding.make_sharding(p_specs, mesh),
            sharding.make_sharding(s_specs, mesh),
            jax.sharding.NamedSharding(
                mesh, jax.sharding.PartitionSpec("data", None)),
        ))
        compiled = jitted.lower(params_abs, state, token).compile()
        assert compiled.cost_analysis() is not None
        print(arch, "decode lowers ok")
    """)
    run_with_devices(code, 8)


def test_collective_parser_on_real_hlo():
    code = textwrap.dedent("""
    import jax, jax.numpy as jnp
    from jax.sharding import AxisType, PartitionSpec as P, NamedSharding
    from repro.launch import roofline as rl

    mesh = jax.make_mesh((4, 2), ("data", "model"),
                         axis_types=(AxisType.Auto,)*2)

    def f(x, w):
        return (x @ w).sum()

    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 32), jnp.float32)
    fn = jax.jit(f, in_shardings=(
        NamedSharding(mesh, P("data", "model")),
        NamedSharding(mesh, P("model", None)),
    ))
    txt = fn.lower(x, w).compile().as_text()
    coll = rl.parse_collective_bytes(txt)
    assert coll["total"] > 0, coll      # contraction over sharded dim
    print("collective parse ok:", {k: v for k, v in coll.items() if v})
    """)
    run_with_devices(code, 8)
