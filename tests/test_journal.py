"""Crash-safe resumable STKDE tests: chunked execution, the durable
progress journal (corruption salvage, fingerprint refusal), SIGKILL
mid-run + bit-identical resume, mesh-shrink re-planning on device loss,
serve partial answers, and the calibrated host planner model."""
import json
import os
import time

import numpy as np
import pytest

from repro.core import Domain, clustered_events, plan
from repro.core.api import stkde, stkde_chunked
from repro.core.datasets import STKDEInstance
from repro.core.pb import pb
from repro.data.pipeline import stkde_stream
from repro.obs import metrics
from repro.resilience import ReproValidationError, faults
from repro.resilience.journal import MAGIC, ProgressJournal, iter_records
from util_subproc import popen_with_devices, run_with_devices

DOM = Domain(gx=32.0, gy=28.0, gt=12.0, sres=1.0, tres=1.0, hs=3.0, ht=2.0)


@pytest.fixture(autouse=True)
def _clean_faults():
    faults.configure("", 0)
    yield
    faults.reset()


def _pts(n=500, seed=7):
    return clustered_events(n, DOM, seed=seed)


# ------------------------------------------------------- fault sites
def test_new_sites_registered():
    assert {"stkde.chunk", "journal.write", "dist.device"} <= set(faults.SITES)
    # wildcard fans out over every named site, new ones included
    rules = faults.parse_spec("*:oom:0.5")
    assert {r.site for r in rules} == set(faults.SITES)


# --------------------------------------------------- chunked == mono
def test_chunked_matches_monolithic():
    pts = _pts()
    mono = np.asarray(stkde(pts, DOM), np.float64)
    res = stkde_chunked(pts, DOM, chunk_size=128)
    assert res.grid.dtype == np.float64
    assert np.allclose(res.grid, mono, rtol=1e-4, atol=1e-6)
    rep = res.report
    assert rep["chunks_total"] == 4 and rep["chunks_computed"] == 4
    assert rep["coverage"] == 1.0
    assert rep["max_chunk_points"] <= 128


def test_chunked_bitwise_deterministic():
    pts = _pts()
    a = stkde_chunked(pts, DOM, chunk_size=128).grid
    b = stkde_chunked(pts, DOM, chunk_size=128).grid
    assert np.array_equal(a, b)


def test_chunk_size_independence_32k_stream():
    """32k-point instance streams through bounded chunks (acceptance:
    peak point-buffer is one chunk) and matches the monolithic grid."""
    inst = STKDEInstance("Kill32k", n=32768, Gx=32, Gy=28, Gt=12,
                         Hs=3, Ht=2, seed=5)
    dom = inst.domain()
    res = stkde_chunked(stkde_stream(inst, chunk=2048), dom)
    rep = res.report
    assert rep["n_total"] == 32768
    assert rep["chunks_total"] == 16
    assert rep["max_chunk_points"] <= 2048  # bounded point buffer
    # second pass of the same stream, materialized, as the reference
    all_pts = np.concatenate(
        [c for c, _ in stkde_stream(inst, chunk=2048)], axis=0)
    mono = np.asarray(pb(all_pts, dom), np.float64)
    assert np.allclose(res.grid, mono, rtol=1e-3, atol=1e-7)


# ------------------------------------------------------ resume paths
def test_partial_then_resume_bit_identical(tmp_path):
    pts = _pts()
    jdir = str(tmp_path / "j")
    ref = stkde_chunked(pts, DOM, chunk_size=128).grid
    part = stkde_chunked(pts, DOM, chunk_size=128, journal=jdir,
                         max_chunks=2)
    assert part.report["truncated"] and part.report["coverage"] < 1.0
    res = stkde_chunked(pts, DOM, chunk_size=128, journal=jdir,
                        resume=True)
    assert res.report["chunks_salvaged"] == 2
    assert res.report["chunks_computed"] == 2
    assert res.report["resumed"] and res.report["coverage"] == 1.0
    assert np.array_equal(res.grid, ref)


def test_truncated_tail_record_recovers(tmp_path):
    pts = _pts()
    jdir = str(tmp_path / "j")
    ref = stkde_chunked(pts, DOM, chunk_size=128, journal=jdir).grid
    jpath = os.path.join(jdir, "journal.bin")
    size = os.path.getsize(jpath)
    with open(jpath, "r+b") as f:  # torn final append (crash mid-write)
        f.truncate(size - 7)
    res = stkde_chunked(pts, DOM, chunk_size=128, journal=jdir,
                        resume=True)
    assert res.report["dropped_tail_records"] == 1
    assert res.report["chunks_computed"] == 1  # only the torn chunk redone
    assert np.array_equal(res.grid, ref)


def test_flipped_crc_byte_recovers(tmp_path):
    pts = _pts()
    jdir = str(tmp_path / "j")
    ref = stkde_chunked(pts, DOM, chunk_size=128, journal=jdir).grid
    jpath = os.path.join(jdir, "journal.bin")
    with open(jpath, "r+b") as f:  # flip one payload byte of the tail
        f.seek(-1, os.SEEK_END)
        b = f.read(1)
        f.seek(-1, os.SEEK_END)
        f.write(bytes([b[0] ^ 0xFF]))
    res = stkde_chunked(pts, DOM, chunk_size=128, journal=jdir,
                        resume=True)
    assert res.report["dropped_tail_records"] >= 1
    assert np.array_equal(res.grid, ref)


def test_lost_snapshots_force_full_recompute(tmp_path):
    """Deep corruption: every snapshot gone -> salvage nothing, recompute
    from chunk 0, still bit-identical (always-correct degradation)."""
    pts = _pts()
    jdir = str(tmp_path / "j")
    ref = stkde_chunked(pts, DOM, chunk_size=128, journal=jdir).grid
    for f in os.listdir(jdir):
        if f.startswith("grid_"):
            os.remove(os.path.join(jdir, f))
    res = stkde_chunked(pts, DOM, chunk_size=128, journal=jdir,
                        resume=True)
    assert res.report["chunks_salvaged"] == 0
    assert res.report["chunks_computed"] == 4
    assert np.array_equal(res.grid, ref)


def test_stale_fingerprint_refuses(tmp_path):
    pts = _pts()
    jdir = str(tmp_path / "j")
    stkde_chunked(pts, DOM, chunk_size=128, journal=jdir, max_chunks=1)
    with pytest.raises(ReproValidationError):  # different chunking
        stkde_chunked(pts, DOM, chunk_size=64, journal=jdir, resume=True)
    other = Domain(gx=16.0, gy=16.0, gt=8.0, sres=1.0, tres=1.0,
                   hs=3.0, ht=2.0)
    with pytest.raises(ReproValidationError):  # different domain
        stkde_chunked(clustered_events(500, other, seed=7), other,
                      chunk_size=128, journal=jdir, resume=True)


def test_stkde_resume_wrapper_recovers_chunk_size(tmp_path):
    pts = _pts()
    jdir = str(tmp_path / "j")
    ref = np.asarray(stkde(pts, DOM, chunk_size=128, journal=jdir))
    again = np.asarray(stkde(pts, DOM, resume=jdir))  # all salvaged
    assert np.array_equal(again, ref)


def test_journal_write_faults_retried(tmp_path):
    """In-flight corruption at journal.write: read-back verify catches
    it, the torn append is truncated and retried, and the run + replay
    still land clean."""
    pts = _pts()
    jdir = str(tmp_path / "j")
    faults.configure("journal.write:corrupt:0.4", seed=1)
    before = metrics.counter("resilience.retries.journal.write").value
    res = stkde_chunked(pts, DOM, chunk_size=128, journal=jdir)
    assert metrics.counter(
        "resilience.retries.journal.write").value > before
    faults.configure("", 0)
    salvage = ProgressJournal(jdir).replay()
    assert salvage.dropped_tail == 0
    assert salvage.grid is not None
    assert np.array_equal(salvage.grid, res.grid)
    for rec in iter_records(jdir):
        assert rec["kind"] in ("meta", "chunk", "event")


def test_journal_wire_format(tmp_path):
    jdir = str(tmp_path / "j")
    stkde_chunked(_pts(), DOM, chunk_size=256, journal=jdir)
    with open(os.path.join(jdir, "journal.bin"), "rb") as f:
        assert f.read(4) == MAGIC
    recs = list(iter_records(jdir))
    assert recs[0]["kind"] == "meta"
    chunk_recs = [r for r in recs if r["kind"] == "chunk"]
    assert [r["chunk_id"] for r in chunk_recs] == [0, 1]
    assert all("grid_crc32" in r for r in chunk_recs)


# --------------------------------------------------- serve partial answer
def test_serve_partial_answer(tmp_path):
    from repro.serve.engine import stkde_partial_answer

    pts = _pts()
    jdir = str(tmp_path / "j")
    part = stkde_chunked(pts, DOM, chunk_size=128, journal=jdir,
                         max_chunks=3)
    ans = stkde_partial_answer(jdir, rescale=False)
    assert ans.coverage == pytest.approx(3 * 128 / 500)
    assert ans.chunks == 3 and ans.n_total == 500
    assert np.array_equal(ans.grid, part.grid)
    scaled = stkde_partial_answer(jdir, rescale=True)
    assert scaled.rescaled
    assert np.allclose(scaled.grid, part.grid / ans.coverage)
    with pytest.raises(ReproValidationError):
        stkde_partial_answer(str(tmp_path / "empty"))


# --------------------------------------------------- host calibration
def test_host_model_calibrated_against_committed_reconcile():
    """plan.HOST is calibrated from results/bench/reconcile.json: every
    registry strategy has a compute row within 5x of its prediction
    (the acceptance band for full-strategy reconciliation)."""
    path = os.path.join(os.path.dirname(__file__), "..",
                        "results", "bench", "reconcile.json")
    reports = json.load(open(path))  # one report dict per benchmarked run
    rows = [r for rep in reports for r in rep["rows"]]
    # the committed run predicts with the calibrated HOST model
    assert all(rep["hw"] == "host" for rep in reports)
    compute = {r["strategy"]: r for r in rows if r["term"] == "compute_s"}
    assert set(plan.probed_strategies()) <= set(compute)
    for strat in plan.probed_strategies():
        r = compute[strat]
        assert r["measured_s"] > 0 and r["predicted_s"] > 0, r
        ratio = r["measured_s"] / r["predicted_s"]
        assert 1 / 5 < ratio < 5, (r, ratio)
    # re-fitting from the same file lands near the committed constants,
    # on both the scatter rate and the dd_lpt tile-path derate
    cal = plan.calibrate_host(path, base=plan.HOST)
    assert 0.5 < cal.peak_flops / plan.HOST.peak_flops < 2.0
    assert 0.5 < cal.mxu_derate / plan.HOST.mxu_derate < 2.0
    # sanity: calibration moved far from the accelerator-class seed
    assert plan.HOST_SEED.peak_flops / plan.HOST.peak_flops > 1e3


def test_shrink_mesh_single_device_exhausts():
    import jax

    from repro.launch.mesh import shrink_mesh

    mesh = jax.sharding.Mesh(
        np.asarray(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    assert shrink_mesh(mesh) is None  # no survivors -> local fallback


# ------------------------------------------------- multi-device paths
MESH_SHRINK_CODE = """
import numpy as np
from repro.core import Domain, clustered_events
from repro.core.api import stkde_chunked
from repro.core.pb import pb
from repro.launch.mesh import make_host_mesh
from repro.resilience import faults

dom = Domain(gx=32., gy=28., gt=12., sres=1., tres=1., hs=3., ht=2.)
pts = clustered_events(600, dom, seed=11)
mesh = make_host_mesh(8)  # (4, 2) ("data", "model")
ref = np.asarray(pb(pts, dom), np.float64)

faults.configure("dist.device:oom:0.4", seed=3)
res = stkde_chunked(pts, dom, mesh=mesh, strategy="dr", chunk_size=100)
faults.configure("", 0)

assert np.allclose(res.grid, ref, rtol=1e-4, atol=1e-6), \\
    np.abs(res.grid - ref).max()
rec = res.report["recovery"]
assert rec, "expected device-loss recovery events"
assert all(e["event"] == "device_lost" for e in rec)
meshes = [tuple(e["from_mesh"]) for e in rec]
assert meshes[0] == (4, 2)
sizes = [int(np.prod(m)) for m in meshes]
assert sizes == sorted(sizes, reverse=True), meshes  # monotone shrink
assert res.report["coverage"] == 1.0
print("OK", len(rec), res.report["final_mesh"])
"""


def test_mesh_shrink_recovery_8dev():
    out = run_with_devices(MESH_SHRINK_CODE, n_devices=8)
    assert out.startswith("OK")


KILL_CODE = """
from repro.core import Domain, clustered_events
from repro.core.api import stkde_chunked
from repro.resilience import faults

dom = Domain(gx=32., gy=28., gt=12., sres=1., tres=1., hs=3., ht=2.)
pts = clustered_events(500, dom, seed=7)
# delay-only fault widens the kill window without touching the math
faults.configure("stkde.chunk:delay:1.0:0.4", seed=0)
stkde_chunked(pts, dom, chunk_size=50, journal={jdir!r})
print("DONE", flush=True)
"""


def test_sigkill_midrun_resume_bit_identical(tmp_path):
    """Acceptance criterion: SIGKILL a journaled chunked run mid-flight,
    resume from the journal, grid is bit-identical to an uninterrupted
    run (rtol=0, atol=0 on the float64 accumulator)."""
    jdir = str(tmp_path / "j")
    snap1 = os.path.join(jdir, "grid_00000001.npy")
    proc = popen_with_devices(KILL_CODE.format(jdir=jdir), n_devices=1)
    try:
        deadline = time.time() + 120
        while time.time() < deadline:  # wait until >= 2 chunks landed
            if os.path.exists(snap1):
                break
            if proc.poll() is not None:
                break
            time.sleep(0.02)
        assert proc.poll() is None, (
            "run finished before we could kill it:\n"
            + proc.stdout.read() + proc.stderr.read())
        proc.kill()  # SIGKILL: no handlers, no atexit, no flush
        rc = proc.wait(timeout=60)
    finally:
        if proc.poll() is None:
            proc.kill()
            proc.wait(timeout=60)
    assert rc == -9
    assert "DONE" not in (proc.stdout.read() or "")

    pts = clustered_events(500, DOM, seed=7)
    ref = stkde_chunked(pts, DOM, chunk_size=50).grid
    res = stkde_chunked(pts, DOM, chunk_size=50, journal=jdir,
                        resume=True)
    assert res.report["resumed"]
    assert res.report["chunks_salvaged"] >= 1
    assert res.report["chunks_computed"] >= 1
    assert np.array_equal(res.grid, ref)  # atol=0, rtol=0
