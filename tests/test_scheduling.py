"""Coloring / critical-path / LPT placement tests (paper §5.2 machinery)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import coloring
from repro.distributed import partition


def _valid_coloring(shape, colors):
    colors = np.asarray(colors).reshape(-1)
    for v, nbrs in coloring._neighbors(shape):
        for u in nbrs:
            if colors[u] == colors[v]:
                return False
    return True


class TestColoring:
    def test_naive_is_valid_8_colors(self):
        shape = (4, 4, 4)
        c = coloring.naive_coloring(shape)
        assert c.max() <= 7
        assert _valid_coloring(shape, c)

    @settings(max_examples=10, deadline=None)
    @given(
        nx=st.integers(1, 5), ny=st.integers(1, 5), nz=st.integers(1, 4),
        seed=st.integers(0, 99),
    )
    def test_load_aware_is_valid(self, nx, ny, nz, seed):
        shape = (nx, ny, nz)
        rng = np.random.default_rng(seed)
        loads = rng.pareto(1.5, nx * ny * nz) * 100
        c = coloring.load_aware_coloring(shape, loads)
        assert _valid_coloring(shape, c)

    def test_load_aware_shortens_critical_path_on_skewed_loads(self):
        """The paper's Fig.12 claim: SCHED coloring <= naive coloring T_inf."""
        shape = (6, 6, 6)
        rng = np.random.default_rng(0)
        loads = rng.pareto(1.0, 6 * 6 * 6) * 100 + 1
        naive = coloring.naive_coloring(shape)
        smart = coloring.load_aware_coloring(shape, loads)
        t_naive = coloring.critical_path(shape, naive, loads)
        t_smart = coloring.critical_path(shape, smart, loads)
        assert t_smart <= t_naive * 1.001

    def test_critical_path_bounds(self):
        shape = (3, 3, 3)
        loads = np.ones(27)
        c = coloring.naive_coloring(shape)
        tinf = coloring.critical_path(shape, c, loads)
        assert loads.max() <= tinf <= loads.sum()

    def test_simulated_schedule_respects_graham(self):
        shape = (5, 5, 3)
        rng = np.random.default_rng(1)
        loads = rng.pareto(1.2, 75) * 50 + 1
        c = coloring.load_aware_coloring(shape, loads)
        T1 = loads.sum()
        Tinf = coloring.critical_path(shape, c, loads)
        for P in (2, 4, 8, 16):
            tp = coloring.simulate_schedule(shape, c, loads, P)
            assert tp <= coloring.graham_bound(T1, Tinf, P) + 1e-6
            assert tp >= max(T1 / P, Tinf) - 1e-6

    def test_replicate_critical_reduces_tinf(self):
        shape = (4, 4, 2)
        loads = np.ones(32)
        loads[0] = 500.0  # one dominating subdomain
        c = coloring.load_aware_coloring(shape, loads)
        t0 = coloring.critical_path(shape, c, loads)
        eff, rep = coloring.replicate_critical(shape, c, loads, P=8)
        t1 = coloring.critical_path(shape, c, eff)
        assert t1 < t0
        assert rep[0] > 1  # the heavy subdomain got replicated


class TestLPT:
    def test_lpt_beats_block_on_skew(self):
        rng = np.random.default_rng(2)
        loads = np.sort(rng.pareto(1.0, 256) * 100)[::-1].copy()
        stats = partition.imbalance_stats(loads, 16)
        assert stats["lpt_makespan"] <= stats["block_makespan"]
        # LPT bound: makespan <= ideal + largest tile (a single dominating
        # tile can't be fixed by placement — that's what PD-REP is for)
        assert stats["lpt_makespan"] <= stats["ideal"] + loads.max() + 1e-9

    @settings(max_examples=20, deadline=None)
    @given(
        n=st.integers(1, 200), P=st.integers(1, 32), seed=st.integers(0, 99)
    )
    def test_lpt_is_complete_and_bounded(self, n, P, seed):
        rng = np.random.default_rng(seed)
        loads = rng.random(n) * 10
        a = partition.lpt_assign(loads, P)
        # every tile assigned exactly once
        assert sorted(t for ts in a.tiles_of_device for t in ts) == list(
            range(n)
        )
        # Graham's 4/3 bound for LPT
        opt_lb = max(loads.max(initial=0.0), loads.sum() / P)
        assert a.makespan <= 4 / 3 * opt_lb + 1e-9

    def test_round_robin_split_conserves_counts(self):
        counts = np.array([[5, 0], [17, 3]])
        out = partition.split_counts_round_robin(counts, 4)
        assert out.shape == (4, 2, 2)
        np.testing.assert_array_equal(out.sum(axis=0), counts)
        assert out.max() - out.min(axis=0).min() <= 5  # near-even


class TestPlanner:
    def test_planner_prefers_pd_for_sparse_large_grid(self):
        """Flu-like: huge grid, few points -> init-bound -> not DR."""
        from repro.core import plan
        from repro.core.geometry import Domain

        dom = Domain(gx=581, gy=1536, gt=5951, sres=1, tres=1, hs=5, ht=7)
        pick, table = plan.choose(dom, 31_478, (16, 16))
        assert pick != "dr"
        assert table["dr"]["init_s"] > table["pd"]["init_s"]

    def test_planner_tables_have_all_strategies(self):
        from repro.core import plan
        from repro.core.geometry import Domain

        dom = Domain(gx=131, gy=61, gt=84, sres=1, tres=1, hs=2, ht=3)
        _, table = plan.choose(dom, 588_189, (2, 16, 16))
        assert set(table) == {"dr", "dd", "pd", "pd_xt", "pd_xyt",
                              "dd_lpt", "hybrid"}
        for v in table.values():
            assert v["total_s"] > 0
