"""Multi-device STKDE strategy tests (subprocess with 8 fake host devices).

Every strategy must agree with the single-device PB-SYM reference to fp32
scatter-vs-reduction tolerance, across mesh shapes and bandwidths.
"""
import textwrap

import pytest

from util_subproc import run_with_devices

COMMON = textwrap.dedent(
    """
    import numpy as np, jax, jax.numpy as jnp
    from jax.sharding import AxisType
    from repro.core import Domain, pb, clustered_events
    from repro.distributed.stkde_dist import (
        stkde_dr, stkde_dd, stkde_pd, stkde_pd_xt, stkde_dd_lpt,
        stkde_hybrid)

    def check(got, want, tag, tol=5e-7):
        d = np.abs(np.asarray(got) - want).max()
        assert d < tol, f"{tag}: maxdiff {d}"
        print(tag, "ok", d)
    """
)


def test_all_strategies_match_reference():
    code = COMMON + textwrap.dedent(
        """
        dom = Domain(gx=48., gy=40., gt=20., sres=1., tres=1., hs=3., ht=2.)
        pts = clustered_events(1500, dom, seed=5)
        want = np.asarray(pb(pts, dom))
        mesh = jax.make_mesh((4, 2), ("data", "model"),
                             axis_types=(AxisType.Auto,)*2)
        check(stkde_dr(pts, dom, mesh), want, "dr")
        check(stkde_dd(pts, dom, mesh), want, "dd")
        check(stkde_pd(pts, dom, mesh), want, "pd")
        check(stkde_pd_xt(pts, dom, mesh), want, "pd_xt")
        check(stkde_dd_lpt(pts, dom, mesh), want, "dd_lpt")
        mesh3 = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                              axis_types=(AxisType.Auto,)*3)
        check(stkde_hybrid(pts, dom, mesh3), want, "hybrid")
        from repro.distributed.stkde_dist import stkde_pd_xyt
        check(stkde_pd_xyt(pts, dom, mesh3), want, "pd_xyt")
        """
    )
    run_with_devices(code, 8)


def test_mesh_shape_sweep():
    code = COMMON + textwrap.dedent(
        """
        dom = Domain(gx=40., gy=36., gt=10., sres=1., tres=1., hs=2., ht=1.)
        pts = clustered_events(700, dom, seed=9)
        want = np.asarray(pb(pts, dom))
        for shape in [(1, 8), (8, 1), (2, 4)]:
            mesh = jax.make_mesh(shape, ("data", "model"),
                                 axis_types=(AxisType.Auto,)*2)
            check(stkde_dd(pts, dom, mesh), want, f"dd{shape}")
            check(stkde_pd(pts, dom, mesh), want, f"pd{shape}")
            check(stkde_pd_xt(pts, dom, mesh), want, f"pd_xt{shape}")
        """
    )
    run_with_devices(code, 8)


def test_nondivisible_grid_padding():
    """Grid dims not divisible by the device grid exercise the pad/slice."""
    code = COMMON + textwrap.dedent(
        """
        dom = Domain(gx=45., gy=34., gt=13., sres=1., tres=1., hs=2., ht=2.)
        pts = clustered_events(600, dom, seed=3)
        want = np.asarray(pb(pts, dom))
        mesh = jax.make_mesh((4, 2), ("data", "model"),
                             axis_types=(AxisType.Auto,)*2)
        check(stkde_dd(pts, dom, mesh), want, "dd-pad")
        check(stkde_pd(pts, dom, mesh), want, "pd-pad")
        check(stkde_dd_lpt(pts, dom, mesh), want, "dd_lpt-pad")
        """
    )
    run_with_devices(code, 8)


def test_pd_rejects_too_small_subdomains():
    code = COMMON + textwrap.dedent(
        """
        dom = Domain(gx=16., gy=16., gt=8., sres=1., tres=1., hs=8., ht=2.)
        pts = clustered_events(100, dom, seed=1)
        mesh = jax.make_mesh((4, 2), ("data", "model"),
                             axis_types=(AxisType.Auto,)*2)
        try:
            stkde_pd(pts, dom, mesh)
        except ValueError as e:
            assert "bandwidth" in str(e)
            print("raised ok")
        else:
            raise AssertionError("expected ValueError")
        """
    )
    run_with_devices(code, 8)


def test_heavy_clustering_with_lpt():
    """All mass in one corner: worst case for block DD, fine for LPT."""
    code = COMMON + textwrap.dedent(
        """
        dom = Domain(gx=64., gy=64., gt=8., sres=1., tres=1., hs=3., ht=1.)
        rng = np.random.default_rng(0)
        pts = (rng.normal(8, 2.0, size=(2000, 3))
                 .clip(0.1, 60).astype(np.float32))
        pts[:, 2] = rng.uniform(0, 7.9, 2000)
        want = np.asarray(pb(pts, dom))
        mesh = jax.make_mesh((4, 2), ("data", "model"),
                             axis_types=(AxisType.Auto,)*2)
        check(stkde_dd_lpt(pts, dom, mesh, tile=(16, 16, 8)), want, "lpt")
        check(stkde_dr(pts, dom, mesh), want, "dr")
        """
    )
    run_with_devices(code, 8)


def test_nocomm_builds_identical_on_single_device():
    """collectives=False probes are numerically identical to the full
    builds on a 1-device mesh (empty ppermute perms contribute zeros,
    size-1 psum is the identity)."""
    code = COMMON + textwrap.dedent(
        """
        from repro.distributed.stkde_dist import (
            prepare_pd, build_pd, prepare_pd_xt, build_pd_xt,
            prepare_pd_xyt, build_pd_xyt, prepare_hybrid)

        dom = Domain(gx=48., gy=48., gt=16., sres=1., tres=1., hs=3., ht=2.)
        pts = clustered_events(1500, dom, seed=7)
        n = len(pts)
        mesh = jax.make_mesh((1, 1, 1), ("pod", "data", "model"),
                             axis_types=(AxisType.Auto,)*3)
        w2 = ("data", "model")

        args = prepare_pd(pts, dom, mesh, w2)
        full = np.asarray(build_pd(dom, mesh, w2, n)(*args))
        noc = np.asarray(build_pd(dom, mesh, w2, n,
                                  collectives=False)(*args))
        np.testing.assert_array_equal(full, noc)
        print("pd ok")

        args = prepare_pd_xt(pts, dom, mesh, w2)
        full = np.asarray(build_pd_xt(dom, mesh, w2, n)(*args))
        noc = np.asarray(build_pd_xt(dom, mesh, w2, n,
                                     collectives=False)(*args))
        np.testing.assert_array_equal(full, noc)
        print("pd_xt ok")

        ax3 = ("pod", "data", "model")
        args = prepare_pd_xyt(pts, dom, mesh, ax3)
        full = np.asarray(build_pd_xyt(dom, mesh, ax3, n)(*args))
        noc = np.asarray(build_pd_xyt(dom, mesh, ax3, n,
                                      collectives=False)(*args))
        np.testing.assert_array_equal(full, noc)
        print("pd_xyt ok")

        args = prepare_hybrid(pts, dom, mesh, w2, rep_axis="pod")
        full = np.asarray(build_pd(dom, mesh, w2, n,
                                   rep_axis="pod")(*args))
        noc = np.asarray(build_pd(dom, mesh, w2, n, rep_axis="pod",
                                  collectives=False)(*args))
        assert noc.shape == (1,) + full.shape
        np.testing.assert_array_equal(full, noc[0])
        print("hybrid ok")
        """
    )
    run_with_devices(code, 1)


def test_nocomm_builds_differ_only_by_halo_terms_8dev():
    """On a real 2x2x2 mesh the collectives=False probes differ from the
    full builds only in the halo bands / rep-psum: subdomain interiors
    more than one bandwidth from a cut boundary are bitwise identical,
    and the boundary bands do differ (comm moves real mass)."""
    code = COMMON + textwrap.dedent(
        """
        from repro.distributed.stkde_dist import (
            prepare_pd, build_pd, prepare_pd_xt, build_pd_xt,
            prepare_pd_xyt, build_pd_xyt, prepare_hybrid)

        dom = Domain(gx=48., gy=48., gt=16., sres=1., tres=1., hs=3., ht=2.)
        pts = clustered_events(1500, dom, seed=7)
        n = len(pts)
        Hs, Ht = dom.Hs, dom.Ht
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                             axis_types=(AxisType.Auto,)*3)
        w2 = ("data", "model")

        def split(tag, full, noc, interior):
            assert full.shape == noc.shape, (tag, full.shape, noc.shape)
            assert (full != noc).any(), tag + ": no halo mass moved"
            np.testing.assert_array_equal(
                full[interior], noc[interior], err_msg=tag)
            print(tag, "ok")

        # pd over the (2, 2) worker grid: 24x24 blocks, Hs-wide x/y halos
        args = prepare_pd(pts, dom, mesh, w2)
        full = np.asarray(build_pd(dom, mesh, w2, n)(*args))
        noc = np.asarray(build_pd(dom, mesh, w2, n,
                                  collectives=False)(*args))
        ix = np.s_[:, :, Hs:-Hs, Hs:-Hs, :]
        split("pd", full, noc, ix)

        # pd_xt: Hs-wide x halos, Ht-wide t halos, y uncut
        args = prepare_pd_xt(pts, dom, mesh, w2)
        full = np.asarray(build_pd_xt(dom, mesh, w2, n)(*args))
        noc = np.asarray(build_pd_xt(dom, mesh, w2, n,
                                     collectives=False)(*args))
        split("pd_xt", full, noc, np.s_[:, :, Hs:-Hs, :, Ht:-Ht])

        # pd_xyt: all three directions cut
        ax3 = ("pod", "data", "model")
        args = prepare_pd_xyt(pts, dom, mesh, ax3)
        full = np.asarray(build_pd_xyt(dom, mesh, ax3, n)(*args))
        noc = np.asarray(build_pd_xyt(dom, mesh, ax3, n,
                                      collectives=False)(*args))
        split("pd_xyt", full, noc,
              np.s_[:, :, :, Hs:-Hs, Hs:-Hs, Ht:-Ht])

        # hybrid: nocomm is rep-stacked; away from halo bands the full
        # build is exactly the rep-sum of the unfolded partials
        args = prepare_hybrid(pts, dom, mesh, w2, rep_axis="pod")
        full = np.asarray(build_pd(dom, mesh, w2, n,
                                   rep_axis="pod")(*args))
        noc = np.asarray(build_pd(dom, mesh, w2, n, rep_axis="pod",
                                  collectives=False)(*args))
        assert noc.shape == (2,) + full.shape
        asm = noc.sum(axis=0)
        assert (full != asm).any(), "hybrid: no halo mass moved"
        ix = np.s_[:, :, Hs:-Hs, Hs:-Hs, :]
        np.testing.assert_allclose(
            full[ix], asm[ix], rtol=1e-6, atol=1e-8, err_msg="hybrid")
        print("hybrid ok")
        """
    )
    run_with_devices(code, 8)


def test_auto_api_on_mesh():
    code = COMMON + textwrap.dedent(
        """
        from repro.core.api import stkde
        dom = Domain(gx=48., gy=32., gt=16., sres=1., tres=1., hs=3., ht=2.)
        pts = clustered_events(900, dom, seed=2)
        want = np.asarray(pb(pts, dom))
        mesh = jax.make_mesh((4, 2), ("data", "model"),
                             axis_types=(AxisType.Auto,)*2)
        check(stkde(pts, dom, mesh=mesh, strategy="auto"), want, "auto")
        """
    )
    run_with_devices(code, 8)
