"""Multi-device STKDE strategy tests (subprocess with 8 fake host devices).

Every strategy must agree with the single-device PB-SYM reference to fp32
scatter-vs-reduction tolerance, across mesh shapes and bandwidths.
"""
import textwrap

import pytest

from util_subproc import run_with_devices

COMMON = textwrap.dedent(
    """
    import numpy as np, jax, jax.numpy as jnp
    from jax.sharding import AxisType
    from repro.core import Domain, pb, clustered_events
    from repro.distributed.stkde_dist import (
        stkde_dr, stkde_dd, stkde_pd, stkde_pd_xt, stkde_dd_lpt,
        stkde_hybrid)

    def check(got, want, tag, tol=5e-7):
        d = np.abs(np.asarray(got) - want).max()
        assert d < tol, f"{tag}: maxdiff {d}"
        print(tag, "ok", d)
    """
)


def test_all_strategies_match_reference():
    code = COMMON + textwrap.dedent(
        """
        dom = Domain(gx=48., gy=40., gt=20., sres=1., tres=1., hs=3., ht=2.)
        pts = clustered_events(1500, dom, seed=5)
        want = np.asarray(pb(pts, dom))
        mesh = jax.make_mesh((4, 2), ("data", "model"),
                             axis_types=(AxisType.Auto,)*2)
        check(stkde_dr(pts, dom, mesh), want, "dr")
        check(stkde_dd(pts, dom, mesh), want, "dd")
        check(stkde_pd(pts, dom, mesh), want, "pd")
        check(stkde_pd_xt(pts, dom, mesh), want, "pd_xt")
        check(stkde_dd_lpt(pts, dom, mesh), want, "dd_lpt")
        mesh3 = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                              axis_types=(AxisType.Auto,)*3)
        check(stkde_hybrid(pts, dom, mesh3), want, "hybrid")
        from repro.distributed.stkde_dist import stkde_pd_xyt
        check(stkde_pd_xyt(pts, dom, mesh3), want, "pd_xyt")
        """
    )
    run_with_devices(code, 8)


def test_mesh_shape_sweep():
    code = COMMON + textwrap.dedent(
        """
        dom = Domain(gx=40., gy=36., gt=10., sres=1., tres=1., hs=2., ht=1.)
        pts = clustered_events(700, dom, seed=9)
        want = np.asarray(pb(pts, dom))
        for shape in [(1, 8), (8, 1), (2, 4)]:
            mesh = jax.make_mesh(shape, ("data", "model"),
                                 axis_types=(AxisType.Auto,)*2)
            check(stkde_dd(pts, dom, mesh), want, f"dd{shape}")
            check(stkde_pd(pts, dom, mesh), want, f"pd{shape}")
            check(stkde_pd_xt(pts, dom, mesh), want, f"pd_xt{shape}")
        """
    )
    run_with_devices(code, 8)


def test_nondivisible_grid_padding():
    """Grid dims not divisible by the device grid exercise the pad/slice."""
    code = COMMON + textwrap.dedent(
        """
        dom = Domain(gx=45., gy=34., gt=13., sres=1., tres=1., hs=2., ht=2.)
        pts = clustered_events(600, dom, seed=3)
        want = np.asarray(pb(pts, dom))
        mesh = jax.make_mesh((4, 2), ("data", "model"),
                             axis_types=(AxisType.Auto,)*2)
        check(stkde_dd(pts, dom, mesh), want, "dd-pad")
        check(stkde_pd(pts, dom, mesh), want, "pd-pad")
        check(stkde_dd_lpt(pts, dom, mesh), want, "dd_lpt-pad")
        """
    )
    run_with_devices(code, 8)


def test_pd_rejects_too_small_subdomains():
    code = COMMON + textwrap.dedent(
        """
        dom = Domain(gx=16., gy=16., gt=8., sres=1., tres=1., hs=8., ht=2.)
        pts = clustered_events(100, dom, seed=1)
        mesh = jax.make_mesh((4, 2), ("data", "model"),
                             axis_types=(AxisType.Auto,)*2)
        try:
            stkde_pd(pts, dom, mesh)
        except ValueError as e:
            assert "bandwidth" in str(e)
            print("raised ok")
        else:
            raise AssertionError("expected ValueError")
        """
    )
    run_with_devices(code, 8)


def test_heavy_clustering_with_lpt():
    """All mass in one corner: worst case for block DD, fine for LPT."""
    code = COMMON + textwrap.dedent(
        """
        dom = Domain(gx=64., gy=64., gt=8., sres=1., tres=1., hs=3., ht=1.)
        rng = np.random.default_rng(0)
        pts = (rng.normal(8, 2.0, size=(2000, 3))
                 .clip(0.1, 60).astype(np.float32))
        pts[:, 2] = rng.uniform(0, 7.9, 2000)
        want = np.asarray(pb(pts, dom))
        mesh = jax.make_mesh((4, 2), ("data", "model"),
                             axis_types=(AxisType.Auto,)*2)
        check(stkde_dd_lpt(pts, dom, mesh, tile=(16, 16, 8)), want, "lpt")
        check(stkde_dr(pts, dom, mesh), want, "dr")
        """
    )
    run_with_devices(code, 8)


def test_auto_api_on_mesh():
    code = COMMON + textwrap.dedent(
        """
        from repro.core.api import stkde
        dom = Domain(gx=48., gy=32., gt=16., sres=1., tres=1., hs=3., ht=2.)
        pts = clustered_events(900, dom, seed=2)
        want = np.asarray(pb(pts, dom))
        mesh = jax.make_mesh((4, 2), ("data", "model"),
                             axis_types=(AxisType.Auto,)*2)
        check(stkde(pts, dom, mesh=mesh, strategy="auto"), want, "auto")
        """
    )
    run_with_devices(code, 8)
