"""Shared test fixtures.

NOTE: tests run with the real single CPU device (no
xla_force_host_platform_device_count here by design — only
launch/dryrun.py sets that, see system requirements). Multi-device tests
spawn subprocesses via ``tests/util_subproc.py``.
"""
import os

# Keep CPU tests deterministic and small-memory.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest

import repro.compat  # noqa: F401  (JAX version shims before test imports)

try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:  # container has no hypothesis; use the shim
    from _hypothesis_shim import install as _install_hypothesis_shim

    _install_hypothesis_shim()


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(autouse=True)
def _reset_obs():
    """Fresh global tracer + metrics registry + fault injector per test
    (all three are process-global by design; tests must not see each
    other's spans, counters, or per-site fault counters)."""
    yield
    from repro.obs import metrics, trace
    from repro.resilience import faults

    trace.reset()
    metrics.reset()
    faults.reset()
