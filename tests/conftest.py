"""Shared test fixtures.

NOTE: tests run with the real single CPU device (no
xla_force_host_platform_device_count here by design — only
launch/dryrun.py sets that, see system requirements). Multi-device tests
spawn subprocesses via ``tests/util_subproc.py``.
"""
import os

# Keep CPU tests deterministic and small-memory.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
