"""End-to-end driver: train a ~100M-param LM for a few hundred steps.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]

Uses the smollm-360m family at ~100M scale (trimmed depth/vocab so CPU
finishes in minutes), the deterministic synthetic pipeline, AdamW with
warmup+cosine, and the fault-tolerant runner (async checkpoints — kill and
re-run to watch it resume). Loss drops from ~ln(4096) to the structured
floor of the Markov stream.
"""
import argparse
import tempfile

from repro.configs import ARCHS
from repro.launch import train as train_driver


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--tiny", action="store_true",
                    help="CPU-smoke scale (~4M params); default is the "
                    "~100M config for real hardware")
    args = ap.parse_args()

    ckpt = args.ckpt_dir or tempfile.mkdtemp(prefix="repro_train_lm_")
    # ~100M params: smollm-360m trimmed (12 layers, vocab 8192)
    base = ARCHS["smollm-360m"]
    cfg = base.replace(
        name="smollm-100m", n_layers=12, vocab=8192,
        compute_dtype="float32", remat=False, max_seq=512,
    )
    batch, seq = "8", "256"
    if args.tiny:
        cfg = cfg.replace(name="smollm-tiny", n_layers=4, d_model=128,
                          n_heads=4, n_kv_heads=4, d_ff=512, vocab=2048)
        batch, seq = "8", "128"
    train_driver.ARCHS[cfg.name] = cfg   # register for the driver
    print(f"params ~= {cfg.param_count() / 1e6:.1f}M")
    summary = train_driver.main([
        "--arch", cfg.name, "--steps", str(args.steps),
        "--batch", batch, "--seq", seq, "--lr", "6e-3",
        "--ckpt-dir", ckpt, "--ckpt-every", "100",
    ])
    assert summary["final_step"] >= args.steps
    print(f"checkpoints in {ckpt} (re-run with --ckpt-dir {ckpt} to resume)")


if __name__ == "__main__":
    main()
