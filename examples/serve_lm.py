"""Batched serving example: mixed-length request queue through both
schedulers — continuous batching (slot-swap, the default) and the
bucketed reference — with identical sampled outputs (docs/serving.md).

    PYTHONPATH=src python examples/serve_lm.py
"""
import numpy as np
import jax

from repro.configs import ARCHS, reduced
from repro.models import init_params
from repro.serve import ServingEngine, EngineConfig


def serve(cfg, params, lens, continuous):
    eng = ServingEngine(cfg, params, EngineConfig(
        max_batch=4, max_seq=128, temperature=0.7, seed=7,
        continuous_batching=continuous,
    ))
    rng = np.random.default_rng(0)
    for uid, L in enumerate(lens):
        eng.submit(uid, rng.integers(0, cfg.vocab, L), max_new=12)
    return eng.run(), eng.last_stats


def main():
    cfg = reduced(ARCHS["mistral-nemo-12b"])   # GQA family, tiny dims
    params = init_params(cfg, jax.random.PRNGKey(0))
    lens = [8, 8, 12, 12, 12, 16, 8, 16]

    out, st = serve(cfg, params, lens, continuous=True)
    for uid in sorted(out):
        print(f"req {uid} (prompt {lens[uid]} toks) -> "
              f"{np.asarray(out[uid]).tolist()}")
    assert len(out) == len(lens)
    idle = (1 - st["active_slot_steps"] / st["slot_steps"]
            if st["slot_steps"] else 0.0)
    print(f"\ncontinuous: {st['swaps']} slot swaps, "
          f"{st['n_tokens']} tokens, slot idle frac {idle:.3f}")

    # the bucketed reference serves the same queue with the same keys —
    # sampling is fold_in(seed, uid, position), not schedule-dependent
    ref, st_b = serve(cfg, params, lens, continuous=False)
    same = all(list(ref[u]) == list(out[u]) for u in out)
    idle_b = (1 - st_b["active_slot_steps"] / st_b["slot_steps"]
              if st_b["slot_steps"] else 0.0)
    print(f"bucketed reference: {len(set(lens))} buckets, "
          f"slot idle frac {idle_b:.3f}, identical outputs: {same}")
    assert same


if __name__ == "__main__":
    main()
