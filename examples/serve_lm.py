"""Batched serving example: mixed-length request queue through the engine.

    PYTHONPATH=src python examples/serve_lm.py
"""
import numpy as np
import jax

from repro.configs import ARCHS, reduced
from repro.models import init_params
from repro.serve import ServingEngine, EngineConfig


def main():
    cfg = reduced(ARCHS["mistral-nemo-12b"])   # GQA family, tiny dims
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, EngineConfig(
        max_batch=4, max_seq=128, temperature=0.7, seed=7,
    ))
    rng = np.random.default_rng(0)
    lens = [8, 8, 12, 12, 12, 16, 8, 16]
    for uid, L in enumerate(lens):
        eng.submit(uid, rng.integers(0, cfg.vocab, L), max_new=12)
    out = eng.run()
    for uid in sorted(out):
        print(f"req {uid} (prompt {lens[uid]} toks) -> {list(out[uid])}")
    assert len(out) == len(lens)
    print(f"\nserved {len(out)} requests in "
          f"{len(set(lens))} same-length buckets")


if __name__ == "__main__":
    main()
