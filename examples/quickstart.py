"""Quickstart: STKDE on a synthetic epidemic, strategy auto-selection.

    PYTHONPATH=src python examples/quickstart.py

Builds a Dengue-like clustered space-time dataset, computes the density
volume with the single-device PB-SYM path and the Pallas tile kernel,
verifies they agree, and prints what the parametric planner (paper §6.5,
implemented in core/plan.py) would choose on a production mesh.
"""
import numpy as np
import jax.numpy as jnp

from repro.core import Domain, pb, clustered_events, bucketing
from repro.core.api import stkde
from repro.core.plan import choose
from repro.kernels import stkde_tiled


def main():
    # a city-scale domain: 30km x 24km at 100m resolution, 120 days
    dom = Domain(gx=30_000, gy=24_000, gt=120, sres=100, tres=1,
                 hs=500, ht=7)
    print(f"domain: {dom.describe()}")
    pts = clustered_events(20_000, dom, seed=42)

    grid = np.asarray(stkde(pts, dom))                 # scatter PB-SYM
    grid_k = np.asarray(stkde_tiled(pts, dom))         # Pallas tile kernel
    err = np.abs(grid - grid_k).max()
    print(f"PB-SYM vs tile-kernel max|diff| = {err:.2e}")
    assert err < 1e-6

    peak = np.unravel_index(grid.argmax(), grid.shape)
    print(f"peak density voxel (x, y, t) = {peak}, "
          f"value = {grid.max():.3e}")
    print(f"total mass = {grid.sum() * dom.sres**2 * dom.tres:.4f} "
          f"(~2/3 per kernel normalization)")

    # what would the planner run on a 256-chip pod?
    tile = (dom.Gx // 16 + 1, dom.Gy // 16 + 1, dom.Gt)
    loads = bucketing.bucket_points_home(pts, dom, tile).counts
    pick, table = choose(dom, len(pts), (16, 16), loads.reshape(-1))
    print(f"\nplanner on a 16x16 pod picks: {pick!r}")
    for name, row in sorted(table.items(), key=lambda kv: kv[1]["total_s"]):
        print(f"  {name:8s} total={row['total_s']*1e3:8.3f}ms "
              f"(init={row['init_s']*1e3:.3f} compute={row['compute_s']*1e3:.3f} "
              f"comm={row['comm_s']*1e3:.3f}) "
              f"{'OK' if row['feasible'] else 'infeasible'}")


if __name__ == "__main__":
    main()
