"""Bandwidth exploration (paper Fig. 1): the near-real-time use case.

    PYTHONPATH=src python examples/stkde_interactive.py

The paper's motivation is interactive visual analytics: an analyst sweeps
spatial/temporal bandwidths and the density volume must recompute in
near-real-time. This example sweeps (hs, ht) over a Dengue-like dataset,
prints per-recompute latency, and renders a coarse ASCII heatmap of one
time slice so the smoothing effect is visible.
"""
import time

import numpy as np
import jax

from repro.core import Domain, pb, clustered_events


ASCII = " .:-=+*#%@"


def ascii_map(slice2d, width=48, height=20):
    h, w = slice2d.shape
    ys = np.linspace(0, h - 1, height).astype(int)
    xs = np.linspace(0, w - 1, width).astype(int)
    sub = slice2d[np.ix_(ys, xs)]
    hi = sub.max() or 1.0
    return "\n".join(
        "".join(ASCII[min(int(v / hi * (len(ASCII) - 1)), len(ASCII) - 1)]
                for v in row)
        for row in sub
    )


def main():
    dom0 = Domain(gx=148, gy=194, gt=112, sres=1, tres=1, hs=3, ht=1)
    pts = clustered_events(11_056, dom0, seed=1)   # Dengue-sized
    print(f"events: {len(pts)}, domain {dom0.describe()}\n")

    for hs, ht in [(3, 1), (10, 3), (25, 7)]:
        dom = dom0.with_bandwidth(float(hs), float(ht))
        grid = pb(pts, dom)                       # compile on first call
        jax.block_until_ready(grid)
        t0 = time.perf_counter()
        grid = pb(pts, dom)
        jax.block_until_ready(grid)
        dt = time.perf_counter() - t0
        g = np.asarray(grid)
        t_peak = int(g.sum(axis=(0, 1)).argmax())
        print(f"hs={hs:3d} ht={ht}  recompute {dt * 1e3:7.1f} ms   "
              f"(peak activity at t={t_peak})")
        print(ascii_map(g[:, :, t_peak].T))
        print()


if __name__ == "__main__":
    main()
