"""LM substrate: model definitions for the 10 assigned architectures."""
from .config import ModelConfig
from . import layers, attention, mla, moe, ssm, rwkv, transformer, model
from .transformer import init_params, forward
from .model import (
    DecodeState,
    init_decode_state,
    decode_step,
    prefill,
)

__all__ = [
    "ModelConfig",
    "layers", "attention", "mla", "moe", "ssm", "rwkv", "transformer",
    "model", "init_params", "forward", "DecodeState", "init_decode_state",
    "decode_step", "prefill",
]
