"""Mamba-2 (SSD) token mixer — chunked scan formulation.

State-space recurrence per head (scalar decay a_t = exp(dt_t * A)):

    h_t = a_t * h_{t-1} + dt_t * B_t ⊗ x_t          h: (P, N)
    y_t = C_t · h_t + D * x_t

Computed chunk-parallel (the SSD algorithm): within a chunk the
(Q, Q) decay-weighted C·B "attention" handles intra-chunk terms; a
sequential lax.scan over chunks carries the (H, P, N) state. This keeps
compile size O(1) in sequence length and memory O(B·Q²·H) per step.

Decode is the exact single-step recurrence on a (conv window, ssm state)
cache — constant memory in context length (the long_500k story).
"""
from __future__ import annotations

import math
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from . import layers
from repro.distributed import sharding as _shard


class SSMCache(NamedTuple):
    conv: jnp.ndarray    # (B, W-1, conv_dim) rolling window
    state: jnp.ndarray   # (B, H, P, N)
    index: jnp.ndarray


def _dims(cfg):
    di = cfg.d_inner_ssm
    H = cfg.n_ssm_heads
    P = cfg.ssm_head_dim
    N = cfg.ssm_state
    G = cfg.ssm_groups
    conv_dim = di + 2 * G * N
    return di, H, P, N, G, conv_dim


def ssm_init(key, cfg) -> dict:
    D = cfg.d_model
    di, H, P, N, G, conv_dim = _dims(cfg)
    ks = jax.random.split(key, 5)
    out_scale = 1.0 / math.sqrt(2 * cfg.n_layers)
    return {
        "in_proj": layers.dense_init(
            ks[0], (D, 2 * di + 2 * G * N + H)
        ),
        "conv_w": layers.dense_init(ks[1], (cfg.ssm_conv, conv_dim),
                                    in_axis=0),
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "A_log": jnp.log(
            jnp.linspace(1.0, 16.0, H).astype(jnp.float32)
        ),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.linspace(1e-3, 1e-1, H).astype(jnp.float32)
        )),
        "norm": layers.norm_init(di),
        "out_proj": layers.dense_init(ks[2], (di, D), scale=out_scale),
    }


def _split_in(cfg, zxbcdt):
    di, H, P, N, G, _ = _dims(cfg)
    z, x, B, C, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + G * N, 2 * di + 2 * G * N], axis=-1
    )
    return z, x, B, C, dt


def _causal_conv(xbc, w, b, window_init=None):
    """Depthwise causal conv along seq. xbc: (B, S, C); w: (W, C)."""
    W = w.shape[0]
    if window_init is None:
        pad = jnp.zeros(xbc.shape[:1] + (W - 1,) + xbc.shape[2:], xbc.dtype)
    else:
        pad = window_init
    full = jnp.concatenate([pad, xbc], axis=1)          # (B, S+W-1, C)
    out = sum(
        full[:, i : i + xbc.shape[1]] * w[i].astype(xbc.dtype)
        for i in range(W)
    )
    return jax.nn.silu(out + b.astype(xbc.dtype)), full[:, -(W - 1):]


def ssm_apply(cfg, p, x, return_cache: bool = False):
    """Training / prefill forward. x: (B, S, D) -> (B, S, D).

    With ``return_cache`` also returns the SSMCache at end of sequence
    (prefill for decode)."""
    dt_ = x.dtype
    B_, S, D = x.shape
    di, H, P, N, G, conv_dim = _dims(cfg)
    Q = min(cfg.ssd_chunk, S)
    while S % Q:
        Q //= 2

    zxbcdt = x @ p["in_proj"].astype(dt_)
    z, xin, Bm, Cm, dt = _split_in(cfg, zxbcdt)
    # §Perf iteration 2 (REFUTED, reverted): replicating the small B/C/dt
    # panels via sharding hints to kill their sliver-permutes cost more than
    # it saved — the constraints perturbed GSPMD propagation around the
    # conv/split and bwd (10.5s -> 16-22s collective). Kept: the channel-
    # separable conv (exact for depthwise), which avoids concat'ing panels
    # with different shardings.
    w, b = p["conv_w"], p["conv_b"]
    xin, win_x = _causal_conv(xin, w[:, :di], b[:di])
    Bm, win_b = _causal_conv(Bm, w[:, di:di + G * N], b[di:di + G * N])
    Cm, win_c = _causal_conv(Cm, w[:, di + G * N:], b[di + G * N:])
    conv_window = jnp.concatenate([win_x, win_b, win_c], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"][None, None])       # (B,S,H)
    A = -jnp.exp(p["A_log"])                               # (H,)
    da = dt * A[None, None]                                # (B,S,H) negative
    xh = xin.reshape(B_, S, H, P)
    Bg = Bm.reshape(B_, S, G, N)
    Cg = Cm.reshape(B_, S, G, N)
    # G == 1: broadcast groups over heads
    Bh = jnp.repeat(Bg, H // G, axis=2)                    # (B,S,H,N)
    Ch = jnp.repeat(Cg, H // G, axis=2)

    nc = S // Q
    dac = da.reshape(B_, nc, Q, H)
    cum = jnp.cumsum(dac, axis=2)                          # inclusive
    xc = xh.reshape(B_, nc, Q, H, P)
    Bc = Bh.reshape(B_, nc, Q, H, N)
    Cc = Ch.reshape(B_, nc, Q, H, N)
    dtc = dt.reshape(B_, nc, Q, H)

    causal = jnp.tril(jnp.ones((Q, Q), bool))

    def chunk_step(h, inputs):
        cumq, xq, bq, cq, dtq = inputs
        # h: (B, H, P, N) state at chunk start (fp32)
        last = cumq[:, -1]                                  # (B,H)
        # intra: att[t,i] = (C_t·B_i) exp(cum_t - cum_i) dt_i,  i<=t
        cb = jnp.einsum("bthn,bihn->bhti", cq, bq)          # (B,H,Q,Q)
        dec = jnp.exp(
            cumq.transpose(0, 2, 1)[:, :, :, None]
            - cumq.transpose(0, 2, 1)[:, :, None, :]
        )                                                   # (B,H,Q,Q)
        att = cb * dec * dtq.transpose(0, 2, 1)[:, :, None, :]
        att = jnp.where(causal[None, None], att, 0.0)
        y_intra = jnp.einsum("bhti,bihp->bthp", att.astype(dt_), xq)
        # inter: y += exp(cum_t) C_t · h
        scale_t = jnp.exp(cumq).astype(dt_)                 # (B,Q,H)
        y_inter = jnp.einsum(
            "bthn,bhpn->bthp", cq * scale_t[..., None], h.astype(dt_)
        )
        # state update: h' = exp(last) h + sum_i exp(last - cum_i) dt_i B_i x_i
        coef = jnp.exp(last[:, None] - cumq) * dtq          # (B,Q,H)
        dh = jnp.einsum("bihn,bihp->bhpn", bq * coef[..., None], xq)
        h_new = jnp.exp(last)[:, :, None, None] * h + dh.astype(jnp.float32)
        return h_new, (y_intra + y_inter)

    h0 = jnp.zeros((B_, H, P, N), jnp.float32)
    scan_in = tuple(
        jnp.moveaxis(a, 1, 0) for a in (cum, xc, Bc, Cc, dtc)
    )
    h_final, yc = jax.lax.scan(chunk_step, h0, scan_in)     # (nc,B,Q,H,P)
    y = jnp.moveaxis(yc, 0, 1).reshape(B_, S, H, P)
    y = y + p["D"].astype(dt_)[None, None, :, None] * xh
    y = y.reshape(B_, S, di)
    y = layers.rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = y @ p["out_proj"].astype(dt_)
    if return_cache:
        cache = SSMCache(conv=conv_window, state=h_final,
                         index=jnp.asarray(S, jnp.int32))
        return out, cache
    return out


def init_cache(cfg, batch: int, dtype) -> SSMCache:
    di, H, P, N, G, conv_dim = _dims(cfg)
    return SSMCache(
        conv=jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype),
        state=jnp.zeros((batch, H, P, N), jnp.float32),
        index=jnp.zeros((), jnp.int32),
    )


def ssm_decode(cfg, p, x, cache: SSMCache) -> Tuple[jnp.ndarray, SSMCache]:
    """Single-token decode. x: (B, 1, D)."""
    dt_ = x.dtype
    B_ = x.shape[0]
    di, H, P, N, G, conv_dim = _dims(cfg)
    zxbcdt = x @ p["in_proj"].astype(dt_)
    z, xin, Bm, Cm, dt = _split_in(cfg, zxbcdt)
    xbc_new = jnp.concatenate([xin, Bm, Cm], -1)            # (B,1,conv)
    xbc, window = _causal_conv(
        xbc_new, p["conv_w"], p["conv_b"], window_init=cache.conv
    )
    xin, Bm, Cm = jnp.split(xbc, [di, di + G * N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None])
    A = -jnp.exp(p["A_log"])
    da = (dt * A[None, None])[:, 0]                         # (B,H)
    xh = xin.reshape(B_, H, P)
    Bh = jnp.repeat(Bm.reshape(B_, G, N), H // G, axis=1)
    Ch = jnp.repeat(Cm.reshape(B_, G, N), H // G, axis=1)
    h = cache.state * jnp.exp(da)[:, :, None, None]
    h = h + jnp.einsum(
        "bhn,bhp,bh->bhpn", Bh.astype(jnp.float32),
        xh.astype(jnp.float32), dt[:, 0]
    )
    y = jnp.einsum("bhn,bhpn->bhp", Ch.astype(jnp.float32), h)
    y = y.astype(dt_) + p["D"].astype(dt_)[None, :, None] * xh
    y = y.reshape(B_, 1, di)
    y = layers.rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    return y @ p["out_proj"].astype(dt_), SSMCache(
        conv=window, state=h, index=cache.index + 1
    )
