"""Attention: GQA self-attention (full + chunked flash-style), cross-attn,
and KV-cache decode. MLA lives in mla.py.

Layouts: activations (B, S, D); q/k/v (B, S, H, dh). KV heads are repeated
to H before the contraction so the head axis shards uniformly over "model".
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from . import layers
from repro.distributed import sharding as _shard


class KVCache(NamedTuple):
    k: jnp.ndarray        # (B, S_max, Hkv, dh)
    v: jnp.ndarray        # (B, S_max, Hkv, dh)
    index: jnp.ndarray    # scalar int32 — next write position


def attn_init(key, cfg, cross: bool = False) -> dict:
    D, H, Hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    out_scale = 1.0 / math.sqrt(2 * cfg.n_layers)
    return {
        "wq": layers.dense_init(ks[0], (D, H * dh)),
        "wk": layers.dense_init(ks[1], (D, Hkv * dh)),
        "wv": layers.dense_init(ks[2], (D, Hkv * dh)),
        "wo": layers.dense_init(ks[3], (H * dh, D), scale=out_scale),
    }


def _split_heads(x, n, dh):
    return x.reshape(x.shape[:-1] + (n, dh))


def _repeat_kv(x, q_per_kv):
    if q_per_kv == 1:
        return x
    return jnp.repeat(x, q_per_kv, axis=2)


def _full_attn(q, k, v, q_pos, kv_pos, causal, window):
    """q: (B,Sq,H,dh), k/v: (B,Skv,H,dh). Returns (B,Sq,H,dh)."""
    dh = q.shape[-1]
    scale = 1.0 / math.sqrt(dh)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) * scale
    scores = scores.astype(jnp.float32)
    mask = jnp.ones(scores.shape[-2:], bool)
    if causal:
        mask &= q_pos[:, None] >= kv_pos[None, :]
    if window > 0:
        mask &= q_pos[:, None] - kv_pos[None, :] < window
    scores = jnp.where(mask[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def _flash_attn(q, k, v, q_pos, kv_pos, causal, window, cq, ckv):
    """Double-chunked online-softmax attention (prefill / long-context train).

    The memory-hierarchy shape of FlashAttention adapted as a lax.scan
    schedule: XLA:TPU keeps the (cq, ckv) score panel in VMEM; no (Sq, Skv)
    intermediate is ever materialized.
    """
    B, Sq, H, dh = q.shape
    Skv = k.shape[1]
    scale = 1.0 / math.sqrt(dh)
    nq = -(-Sq // cq)
    nk = -(-Skv // ckv)
    pq = nq * cq - Sq
    pk = nk * ckv - Skv
    q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
    v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    q_pos = jnp.pad(q_pos, (0, pq), constant_values=-1)       # masked out
    kv_pos = jnp.pad(kv_pos, (0, pk), constant_values=2**30)  # masked out

    qc = q.reshape(B, nq, cq, H, dh).transpose(1, 0, 3, 2, 4)   # (nq,B,H,cq,dh)
    kc = k.reshape(B, nk, ckv, H, dh).transpose(1, 0, 3, 2, 4)
    vc = v.reshape(B, nk, ckv, H, dh).transpose(1, 0, 3, 2, 4)
    qpc = q_pos.reshape(nq, cq)
    kpc = kv_pos.reshape(nk, ckv)

    def q_step(_, qi):
        qblk, qp = qi                                           # (B,H,cq,dh)

        def kv_step(carry, ki):
            m, l, acc = carry
            kblk, vblk, kp = ki
            s = jnp.einsum("bhqd,bhkd->bhqk", qblk, kblk) * scale
            s = s.astype(jnp.float32)
            msk = jnp.ones((cq, ckv), bool)
            if causal:
                msk &= qp[:, None] >= kp[None, :]
            if window > 0:
                msk &= qp[:, None] - kp[None, :] < window
            s = jnp.where(msk[None, None], s, -1e30)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bhkd->bhqd", p.astype(qblk.dtype), vblk
            ).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        init = (
            jnp.full((B, H, cq), -jnp.inf, jnp.float32),
            jnp.zeros((B, H, cq), jnp.float32),
            jnp.zeros((B, H, cq, dh), jnp.float32),
        )
        (m, l, acc), _ = jax.lax.scan(kv_step, init, (kc, vc, kpc))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out.astype(q.dtype)

    _, out = jax.lax.scan(q_step, None, (qc, qpc))              # (nq,B,H,cq,dh)
    out = out.transpose(1, 0, 3, 2, 4).reshape(B, nq * cq, H, dh)
    return out[:, :Sq]


def attn_apply(
    cfg,
    p: dict,
    x: jnp.ndarray,                      # (B, S, D)
    positions: jnp.ndarray,              # (S,)
    causal: bool = True,
    kv_source: Optional[jnp.ndarray] = None,   # cross-attention memory
    use_rope: bool = True,
) -> jnp.ndarray:
    """Training / prefill self- or cross-attention (no cache)."""
    dt = x.dtype
    B, S, D = x.shape
    H, Hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    src = x if kv_source is None else kv_source
    q = _split_heads(x @ p["wq"].astype(dt), H, dh)
    k = _split_heads(src @ p["wk"].astype(dt), Hkv, dh)
    v = _split_heads(src @ p["wv"].astype(dt), Hkv, dh)
    kv_pos = positions if kv_source is None else jnp.arange(src.shape[1])
    if use_rope and kv_source is None:
        q = layers.apply_rope(q, positions[None], cfg.rope_theta)
        k = layers.apply_rope(k, kv_pos[None], cfg.rope_theta)
    k = _repeat_kv(k, cfg.q_per_kv)
    v = _repeat_kv(v, cfg.q_per_kv)
    Skv = k.shape[1]
    if S * Skv > 4 * 1024 * 1024:
        out = _flash_attn(
            q, k, v, positions, kv_pos, causal, cfg.sliding_window,
            cfg.attn_chunk_q, cfg.attn_chunk_kv,
        )
    else:
        out = _full_attn(q, k, v, positions, kv_pos, causal,
                         cfg.sliding_window)
    return out.reshape(B, S, H * dh) @ p["wo"].astype(dt)


def init_cache(cfg, batch: int, max_seq: int, dtype) -> KVCache:
    Hkv, dh = cfg.n_kv_heads, cfg.head_dim
    return KVCache(
        k=jnp.zeros((batch, max_seq, Hkv, dh), dtype),
        v=jnp.zeros((batch, max_seq, Hkv, dh), dtype),
        index=jnp.zeros((), jnp.int32),
    )


def attn_decode(
    cfg,
    p: dict,
    x: jnp.ndarray,                     # (B, 1, D)
    cache: KVCache,
    use_rope: bool = True,
    positions: Optional[jnp.ndarray] = None,   # (B,) per-row cursors
) -> Tuple[jnp.ndarray, KVCache]:
    """One-token decode against a dense KV cache.

    With ``positions=None`` every row writes/reads at the shared scalar
    ``cache.index`` cursor (bucketed serving, all rows in lockstep). With
    ``positions`` of shape (B,) each row keeps its own sequence position —
    the continuous-batching slot-swap mode, where rows at different depths
    share one cache pool and ``cache.index`` is ignored.
    """
    dt = x.dtype
    B, _, D = x.shape
    H, Hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    idx = cache.index
    q = _split_heads(x @ p["wq"].astype(dt), H, dh)
    k_new = _split_heads(x @ p["wk"].astype(dt), Hkv, dh)
    v_new = _split_heads(x @ p["wv"].astype(dt), Hkv, dh)
    if use_rope:
        pos = idx[None, None] if positions is None else positions[:, None]
        q = layers.apply_rope(q, pos, cfg.rope_theta)
        k_new = layers.apply_rope(k_new, pos, cfg.rope_theta)
    if positions is None:
        k_cache = jax.lax.dynamic_update_slice(
            cache.k, k_new.astype(cache.k.dtype), (0, idx, 0, 0)
        )
        v_cache = jax.lax.dynamic_update_slice(
            cache.v, v_new.astype(cache.v.dtype), (0, idx, 0, 0)
        )
    else:
        rows = jnp.arange(B)
        k_cache = cache.k.at[rows, positions].set(
            k_new[:, 0].astype(cache.k.dtype), mode="drop"
        )
        v_cache = cache.v.at[rows, positions].set(
            v_new[:, 0].astype(cache.v.dtype), mode="drop"
        )
    kv_pos = jnp.arange(cache.k.shape[1])
    # Flash-decoding layout (§Perf iteration 2): replicate the tiny q over
    # "model" and keep the cache (and thus the score panel) sequence-sharded
    # — without the hint GSPMD re-shards the whole cache to q's head
    # sharding, all-gathering seq_len*Hkv*dh bytes per layer per step.
    q = _shard.hint(q, "batch", None, None, None)
    k = _repeat_kv(k_cache.astype(dt), cfg.q_per_kv)
    v = _repeat_kv(v_cache.astype(dt), cfg.q_per_kv)
    scale = 1.0 / math.sqrt(dh)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k) * scale
    s = _shard.hint(s, "batch", None, None, "seq")
    s = s.astype(jnp.float32)
    if positions is None:
        valid = kv_pos <= idx
        if cfg.sliding_window > 0:
            valid &= idx - kv_pos < cfg.sliding_window
        s = jnp.where(valid[None, None, None, :], s, -1e30)
    else:
        valid = kv_pos[None, :] <= positions[:, None]          # (B, S)
        if cfg.sliding_window > 0:
            valid &= positions[:, None] - kv_pos[None, :] < cfg.sliding_window
        s = jnp.where(valid[:, None, None, :], s, -1e30)
    probs = jax.nn.softmax(s, axis=-1).astype(dt)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v)
    out = out.reshape(B, 1, H * dh) @ p["wo"].astype(dt)
    return out, KVCache(k=k_cache, v=v_cache, index=idx + 1)
