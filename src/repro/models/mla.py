"""Multi-head Latent Attention (DeepSeek-V2) — compressed-KV attention.

Train/prefill uses the naive (expanded) formulation; decode uses the
*absorbed* formulation: the up-projections w_uk / w_uv are folded into the
query / output sides so the cache stays in latent space (kv_lora + rope dims
per token instead of 2·H·dh) and no per-step expansion of the cache occurs —
DeepSeek's serving trick, which is what makes the decode roofline
memory-term small.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from . import layers
from repro.distributed import sharding as _shard


class MLACache(NamedTuple):
    c_kv: jnp.ndarray     # (B, S_max, kv_lora)
    k_rope: jnp.ndarray   # (B, S_max, rope_dims)
    index: jnp.ndarray


def mla_init(key, cfg) -> dict:
    D, H = cfg.d_model, cfg.n_heads
    r, dn, dv = cfg.kv_lora, cfg.qk_nope_dims, cfg.v_head_dim
    dr = cfg.qk_rope_dims
    ks = jax.random.split(key, 6)
    out_scale = 1.0 / math.sqrt(2 * cfg.n_layers)
    return {
        "wq": layers.dense_init(ks[0], (D, H * (dn + dr))),
        "w_dkv": layers.dense_init(ks[1], (D, r)),
        "w_krope": layers.dense_init(ks[2], (D, dr)),
        "kv_norm": layers.norm_init(r),
        "w_uk": layers.dense_init(ks[3], (r, H * dn)),
        "w_uv": layers.dense_init(ks[4], (r, H * dv)),
        "wo": layers.dense_init(ks[5], (H * dv, D), scale=out_scale),
    }


def _project_q(cfg, p, x, positions):
    """positions: (S,) shared across the batch, or (B, S) per-row."""
    B, S, _ = x.shape
    H, dn, dr = cfg.n_heads, cfg.qk_nope_dims, cfg.qk_rope_dims
    q = (x @ p["wq"].astype(x.dtype)).reshape(B, S, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    pos = positions if positions.ndim == 2 else positions[None]
    q_rope = layers.apply_rope(q_rope, pos, cfg.rope_theta)
    return q_nope, q_rope


def mla_apply(cfg, p, x, positions, causal: bool = True) -> jnp.ndarray:
    """Naive (expanded) MLA for train / prefill."""
    dt = x.dtype
    B, S, D = x.shape
    H = cfg.n_heads
    dn, dr, dv, r = (cfg.qk_nope_dims, cfg.qk_rope_dims, cfg.v_head_dim,
                     cfg.kv_lora)
    q_nope, q_rope = _project_q(cfg, p, x, positions)
    c_kv = layers.rms_norm(x @ p["w_dkv"].astype(dt), p["kv_norm"],
                           cfg.norm_eps)
    k_rope = layers.apply_rope(
        (x @ p["w_krope"].astype(dt))[:, :, None, :], positions[None],
        cfg.rope_theta,
    )                                                     # (B,S,1,dr)
    k_nope = (c_kv @ p["w_uk"].astype(dt)).reshape(B, S, H, dn)
    v = (c_kv @ p["w_uv"].astype(dt)).reshape(B, S, H, dv)
    scale = 1.0 / math.sqrt(dn + dr)
    s = (
        jnp.einsum("bqhd,bkhd->bhqk", q_nope, k_nope)
        + jnp.einsum("bqhd,bkd->bhqk", q_rope, k_rope[:, :, 0, :])
    ) * scale
    s = s.astype(jnp.float32)
    if causal:
        mask = positions[:, None] >= positions[None, :]
        s = jnp.where(mask[None, None], s, -1e30)
    probs = jax.nn.softmax(s, -1).astype(dt)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v)
    return out.reshape(B, S, H * dv) @ p["wo"].astype(dt)


def init_cache(cfg, batch: int, max_seq: int, dtype) -> MLACache:
    return MLACache(
        c_kv=jnp.zeros((batch, max_seq, cfg.kv_lora), dtype),
        k_rope=jnp.zeros((batch, max_seq, cfg.qk_rope_dims), dtype),
        index=jnp.zeros((), jnp.int32),
    )


def mla_decode(cfg, p, x, cache: MLACache,
               positions=None) -> Tuple[jnp.ndarray, MLACache]:
    """Absorbed-matrix decode: scores and values in latent space.

    ``positions`` (B,) switches to per-row cursors (continuous batching);
    the scalar ``cache.index`` cursor is used — and advanced — otherwise.
    """
    dt = x.dtype
    B = x.shape[0]
    H = cfg.n_heads
    dn, dr, dv, r = (cfg.qk_nope_dims, cfg.qk_rope_dims, cfg.v_head_dim,
                     cfg.kv_lora)
    idx = cache.index
    pos = idx[None, None] if positions is None else positions[:, None]
    q_nope, q_rope = _project_q(cfg, p, x, pos if positions is not None
                                else pos[0])
    c_new = layers.rms_norm(x @ p["w_dkv"].astype(dt), p["kv_norm"],
                            cfg.norm_eps)
    kr_new = layers.apply_rope(
        (x @ p["w_krope"].astype(dt))[:, :, None, :], pos, cfg.rope_theta
    )[:, :, 0, :]
    if positions is None:
        c_kv = jax.lax.dynamic_update_slice(
            cache.c_kv, c_new.astype(cache.c_kv.dtype), (0, idx, 0)
        )
        k_rope = jax.lax.dynamic_update_slice(
            cache.k_rope, kr_new.astype(cache.k_rope.dtype), (0, idx, 0)
        )
    else:
        rows = jnp.arange(B)
        c_kv = cache.c_kv.at[rows, positions].set(
            c_new[:, 0].astype(cache.c_kv.dtype), mode="drop"
        )
        k_rope = cache.k_rope.at[rows, positions].set(
            kr_new[:, 0].astype(cache.k_rope.dtype), mode="drop"
        )
    # absorb w_uk into the query:  q_lat[h, r] = q_nope[h, dn] @ w_uk[r, h, dn]
    w_uk = p["w_uk"].astype(dt).reshape(r, H, dn)
    q_lat = jnp.einsum("bqhd,rhd->bqhr", q_nope, w_uk)    # (B,1,H,r)
    # flash-decoding layout: q replicated, latent cache stays seq-sharded
    q_lat = _shard.hint(q_lat, "batch", None, None, None)
    q_rope = _shard.hint(q_rope, "batch", None, None, None)
    scale = 1.0 / math.sqrt(dn + dr)
    s = (
        jnp.einsum("bqhr,bkr->bhqk", q_lat, c_kv.astype(dt))
        + jnp.einsum("bqhd,bkd->bhqk", q_rope, k_rope.astype(dt))
    ) * scale
    s = _shard.hint(s, "batch", None, None, "seq")
    s = s.astype(jnp.float32)
    kv_pos = jnp.arange(c_kv.shape[1])
    if positions is None:
        s = jnp.where((kv_pos <= idx)[None, None, None, :], s, -1e30)
    else:
        valid = kv_pos[None, :] <= positions[:, None]          # (B, S)
        s = jnp.where(valid[:, None, None, :], s, -1e30)
    probs = jax.nn.softmax(s, -1).astype(dt)
    ctx = jnp.einsum("bhqk,bkr->bqhr", probs, c_kv.astype(dt))  # latent ctx
    w_uv = p["w_uv"].astype(dt).reshape(r, H, dv)
    out = jnp.einsum("bqhr,rhd->bqhd", ctx, w_uv)
    out = out.reshape(B, 1, H * dv) @ p["wo"].astype(dt)
    return out, MLACache(c_kv=c_kv, k_rope=k_rope, index=idx + 1)
