"""Transformer assembly: blocks, scan-over-layers, enc-dec, hybrid patterns.

Compile-time posture: layers are stacked (leading L axis) and executed with
``lax.scan`` so HLO size and compile time are depth-independent — essential
for the 512-device dry-runs (81-layer zamba2 compiles as one block).

Families:
  dense / moe        [attn | mla] + [swiglu | gelu | moe]
  ssm                rwkv6 (tmix + cmix)  or  mamba2 + swiglu
  hybrid (zamba2)    mamba2 stack; one *shared* attention block applied every
                     k layers (weights shared, per-site KV caches)
  audio (whisper)    encoder (bidirectional attn over stub frame embeddings)
                     + decoder with cross-attention
  vlm (llava)        decoder over [vision stub embeds ; text embeds]
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from . import layers, attention, mla, moe, ssm, rwkv
from .attention import KVCache


# ============================================================ init
def _block_init(key, cfg, cross: bool = False) -> dict:
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {"norm1": layers.norm_init(cfg.d_model),
                         "norm2": layers.norm_init(cfg.d_model)}
    if cfg.mixer == "attn":
        if cfg.mla:
            p["mla"] = mla.mla_init(ks[0], cfg)
        else:
            p["attn"] = attention.attn_init(ks[0], cfg)
    elif cfg.mixer == "mamba2":
        p["ssm"] = ssm.ssm_init(ks[0], cfg)
    elif cfg.mixer == "rwkv6":
        p["tmix"] = rwkv.tmix_init(ks[0], cfg)
    if cross:
        p["xattn"] = attention.attn_init(ks[1], cfg)
        p["norm_x"] = layers.norm_init(cfg.d_model)
    if cfg.mlp == "moe":
        p["moe"] = moe.moe_init(ks[2], cfg)
    elif cfg.mlp == "rwkv6_cmix":
        p["cmix"] = rwkv.cmix_init(ks[2], cfg)
    elif cfg.mlp != "none":
        p["mlp"] = layers.mlp_init(ks[2], cfg)
    return p


def _dense_block_init(key, cfg) -> dict:
    """MoE models with dense first layers need a dense twin of the block."""
    ks = jax.random.split(key, 2)
    return {
        "norm1": layers.norm_init(cfg.d_model),
        "norm2": layers.norm_init(cfg.d_model),
        "mlp": layers.mlp_init(ks[1], cfg.replace(mlp="swiglu")),
    }


def init_params(cfg, key) -> dict:
    keys = jax.random.split(key, 8)
    params: Dict[str, Any] = {"embed": layers.embedding_init(keys[0], cfg)}

    def stack_init(key, n, fn):
        ks = jax.random.split(key, n)
        trees = [fn(k) for k in ks]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)

    if cfg.enc_dec:
        params["enc_blocks"] = stack_init(
            keys[1], cfg.n_enc_layers, lambda k: _block_init(k, cfg)
        )
        params["enc_norm"] = layers.norm_init(cfg.d_model)
        params["blocks"] = stack_init(
            keys[2], cfg.n_layers, lambda k: _block_init(k, cfg, cross=True)
        )
    else:
        params["blocks"] = stack_init(
            keys[2], cfg.n_layers, lambda k: _block_init(k, cfg)
        )
    if cfg.mlp == "moe" and cfg.first_dense_layers > 0:
        # deepseek: first layer(s) use a dense FFN; stored separately and
        # swapped in by layer index inside the scan.
        params["dense_mlp"] = stack_init(
            keys[3], cfg.first_dense_layers,
            lambda k: layers.mlp_init(k, cfg.replace(mlp="swiglu")),
        )
    if cfg.shared_attn_every > 0:
        shared_cfg = cfg.replace(mixer="attn")
        params["shared_attn"] = attention.attn_init(keys[4], shared_cfg)
        params["shared_norm"] = layers.norm_init(cfg.d_model)
    params["final_norm"] = layers.norm_init(cfg.d_model)
    head = layers.unembed_init(keys[5], cfg)
    if head is not None:
        params["head"] = head
    return params


# ============================================================ forward (train)
def _apply_mixer(cfg, p, x, positions):
    if cfg.mixer == "attn":
        if cfg.mla:
            return mla.mla_apply(cfg, p["mla"], x, positions)
        return attention.attn_apply(cfg, p["attn"], x, positions,
                                    use_rope=cfg.use_rope)
    if cfg.mixer == "mamba2":
        return ssm.ssm_apply(cfg, p["ssm"], x)
    if cfg.mixer == "rwkv6":
        return rwkv.tmix_apply(cfg, p["tmix"], x)
    raise ValueError(cfg.mixer)


def _apply_channel(cfg, p, x, layer_idx=None):
    """Returns (out, aux)."""
    if cfg.mlp == "moe":
        if cfg.first_dense_layers > 0 and "dense_mlp" in p:
            # first-dense swap: cond on the (traced) layer index
            def dense(x):
                dp = jax.tree.map(
                    lambda a: a[jnp.minimum(layer_idx,
                                            cfg.first_dense_layers - 1)],
                    p["dense_mlp"],
                )
                return layers.mlp_apply(cfg, dp, x), jnp.float32(0)

            def routed(x):
                return moe.moe_apply(cfg, p["moe"], x)

            return jax.lax.cond(
                layer_idx < cfg.first_dense_layers, dense, routed, x
            )
        if cfg.moe_impl == "a2a":
            from repro.distributed import sharding as _sh
            mesh = _sh._HINT_MESH.get()
            if mesh is not None:
                return moe.moe_apply_a2a(cfg, p["moe"], x, mesh)
        return moe.moe_apply(cfg, p["moe"], x)
    if cfg.mlp == "rwkv6_cmix":
        return rwkv.cmix_apply(cfg, p["cmix"], x), jnp.float32(0)
    if cfg.mlp == "none":
        return jnp.zeros_like(x), jnp.float32(0)
    return layers.mlp_apply(cfg, p["mlp"], x), jnp.float32(0)


def _block_apply(cfg, bp, x, positions, layer_idx, shared=None,
                 enc_out=None):
    """One block: mixer + (optional shared attn / cross attn) + channel."""
    x = x + _apply_mixer(cfg, bp, layers.apply_norm(cfg, x, bp["norm1"]),
                         positions)
    if shared is not None:
        sp, snorm, flag = shared
        scfg = cfg.replace(mixer="attn")

        def with_attn(x):
            return x + attention.attn_apply(
                scfg, sp, layers.apply_norm(cfg, x, snorm), positions,
                use_rope=cfg.use_rope,
            )

        x = jax.lax.cond(flag, with_attn, lambda x: x, x)
    if enc_out is not None:
        x = x + attention.attn_apply(
            cfg, bp["xattn"], layers.apply_norm(cfg, x, bp["norm_x"]),
            positions, causal=False, kv_source=enc_out, use_rope=False,
        )
    h, aux = _apply_channel(
        cfg, bp, layers.apply_norm(cfg, x, bp["norm2"]), layer_idx
    )
    return x + h, aux


def _scan_blocks(cfg, params, blocks, x, positions, enc_out=None):
    """lax.scan over stacked blocks (or an unrolled python loop when
    cfg.scan_layers=False — used by the roofline depth-delta analysis, where
    while-loop bodies would be cost-counted only once). Returns (x, aux)."""
    L = jax.tree.leaves(blocks)[0].shape[0]
    dense_mlp = params.get("dense_mlp")

    if not cfg.scan_layers:
        aux = jnp.float32(0)
        for i in range(L):
            bp = jax.tree.map(lambda a: a[i], blocks)
            if dense_mlp is not None:
                if i < cfg.first_dense_layers:
                    dmlp = jax.tree.map(lambda a: a[i], dense_mlp)
                    bp = dict(bp, mlp=dmlp)
                    sub = cfg.replace(mlp="swiglu")
                else:
                    sub = cfg
            else:
                sub = cfg
            shared = None
            if cfg.shared_attn_every > 0 and (
                i % cfg.shared_attn_every == cfg.shared_attn_every - 1
            ):
                shared = (params["shared_attn"], params["shared_norm"],
                          jnp.asarray(True))
            x, a = _block_apply(sub, bp, x, positions, jnp.asarray(i),
                                shared=shared, enc_out=enc_out)
            aux = aux + a
        return x, aux

    flags = None
    if cfg.shared_attn_every > 0:
        idxs = jnp.arange(L)
        flags = (idxs % cfg.shared_attn_every) == (cfg.shared_attn_every - 1)

    def body(carry, inp):
        x, aux = carry
        if flags is not None:
            bp, li, flag = inp
            shared = (params["shared_attn"], params["shared_norm"], flag)
        else:
            bp, li = inp
            shared = None
        if dense_mlp is not None:
            bp = dict(bp, dense_mlp=dense_mlp)
        x, a = _block_apply(cfg, bp, x, positions, li, shared=shared,
                            enc_out=enc_out)
        return (x, aux + a), None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    xs = (blocks, jnp.arange(L))
    if flags is not None:
        xs = xs + (flags,)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0)), xs)
    return x, aux


def encode(cfg, params, frames: jnp.ndarray) -> jnp.ndarray:
    """Whisper encoder over stub frame embeddings (B, S_enc, D)."""
    dt = jnp.dtype(cfg.compute_dtype)
    S = frames.shape[1]
    x = frames.astype(dt) + layers.sinusoidal_positions(
        S, cfg.d_model
    ).astype(dt)[None]
    positions = jnp.arange(S)

    enc_cfg = cfg.replace(mixer="attn", mla=False, mlp="gelu")

    def one(x, bp):
        x = x + attention.attn_apply(
            enc_cfg, bp["attn"],
            layers.apply_norm(cfg, x, bp["norm1"]), positions,
            causal=False, use_rope=False,
        )
        h, _ = _apply_channel(enc_cfg, bp, layers.apply_norm(
            cfg, x, bp["norm2"]))
        return x + h

    if not cfg.scan_layers:
        Le = jax.tree.leaves(params["enc_blocks"])[0].shape[0]
        for i in range(Le):
            x = one(x, jax.tree.map(lambda a: a[i], params["enc_blocks"]))
        return layers.apply_norm(cfg, x, params["enc_norm"])

    def body(carry, bp):
        x, _ = carry
        return (one(x, bp), jnp.float32(0)), None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    (x, _), _ = jax.lax.scan(
        body, (x, jnp.float32(0)), params["enc_blocks"]
    )
    return layers.apply_norm(cfg, x, params["enc_norm"])


def forward(
    cfg,
    params,
    tokens: jnp.ndarray,                        # (B, S_text)
    vision_embeds: Optional[jnp.ndarray] = None,  # (B, S_img, D) vlm stub
    audio_frames: Optional[jnp.ndarray] = None,   # (B, S_enc, D) audio stub
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Training/eval forward. Returns (logits fp32 (B, S_total, V), aux)."""
    dt = jnp.dtype(cfg.compute_dtype)
    x = params["embed"]["tok"].astype(dt)[tokens]
    if vision_embeds is not None:
        x = jnp.concatenate([vision_embeds.astype(dt), x], axis=1)
    S = x.shape[1]
    positions = jnp.arange(S)
    enc_out = None
    if cfg.enc_dec:
        assert audio_frames is not None
        enc_out = encode(cfg, params, audio_frames)
        x = x + layers.sinusoidal_positions(S, cfg.d_model).astype(dt)[None]
    x, aux = _scan_blocks(cfg, params, params["blocks"], x, positions,
                          enc_out=enc_out)
    x = layers.apply_norm(cfg, x, params["final_norm"])
    return layers.logits_from_hidden(cfg, params, x), aux
