"""RWKV-6 ("Finch") token mixer — data-dependent decay linear attention.

Per head (dh-dim keys/values), per-channel decay w_t ∈ (0,1):

    S_t = diag(w_t) S_{t-1} + k_t v_tᵀ                 S: (dh_k, dh_v)
    y_t = r_tᵀ (S_{t-1} + diag(u) k_t v_tᵀ)

Chunk-parallel formulation: the intra-chunk pairwise decay exponent
``cw_{t-1} - cw_i ≤ 0`` is materialized per (T, T, channel) tile — exact and
overflow-free (a rank-1 factorization is NOT numerically safe with
data-dependent decays); inter-chunk terms ride a lax.scan-carried state.

Decode is the exact recurrence on a constant-size state — the attn-free
long_500k story for rwkv6-3b.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from . import layers

LORA_MIX = 32
LORA_DECAY = 64


class RWKVCache(NamedTuple):
    shift_tmix: jnp.ndarray   # (B, D) previous token (time-mix)
    shift_cmix: jnp.ndarray   # (B, D) previous token (channel-mix)
    wkv: jnp.ndarray          # (B, H, dh, dh) state
    index: jnp.ndarray


def _dims(cfg):
    D = cfg.d_model
    dh = 64
    H = D // dh
    return D, H, dh


def tmix_init(key, cfg) -> dict:
    D, H, dh = _dims(cfg)
    ks = jax.random.split(key, 16)
    out_scale = 1.0 / math.sqrt(2 * cfg.n_layers)
    p = {
        "mu_base": jnp.full((D,), 0.5, jnp.float32),
        "wo": layers.dense_init(ks[5], (D, D), scale=out_scale),
        "u": jnp.zeros((H, dh), jnp.float32),
        "w0": jnp.full((D,), -1.5, jnp.float32),
        "w_A": layers.dense_init(ks[6], (D, LORA_DECAY), scale=0.1),
        "w_B": layers.dense_init(ks[7], (LORA_DECAY, D), scale=0.1),
        "ln_w": layers.norm_init(D),
    }
    for i, c in enumerate(("r", "k", "v", "g")):
        p[f"w{c}"] = layers.dense_init(ks[i], (D, D))
        p[f"mu_{c}"] = jnp.full((D,), 0.5, jnp.float32)
        p[f"mix_A_{c}"] = layers.dense_init(ks[8 + i], (D, LORA_MIX),
                                            scale=0.1)
        p[f"mix_B_{c}"] = layers.dense_init(ks[12 + i], (LORA_MIX, D),
                                            scale=0.1)
    return p


def cmix_init(key, cfg) -> dict:
    D = cfg.d_model
    F = cfg.d_ff
    ks = jax.random.split(key, 3)
    out_scale = 1.0 / math.sqrt(2 * cfg.n_layers)
    return {
        "mu_k": jnp.full((D,), 0.5, jnp.float32),
        "mu_r": jnp.full((D,), 0.5, jnp.float32),
        "wk": layers.dense_init(ks[0], (D, F)),
        "wv": layers.dense_init(ks[1], (F, D), scale=out_scale),
        "wr": layers.dense_init(ks[2], (D, D)),
    }


def _token_shift(x, prev):
    """x: (B,S,D); prev: (B,D) last token of previous segment."""
    return jnp.concatenate([prev[:, None], x[:, :-1]], axis=1)


def _ddlerp(p, c, x, xprev):
    """RWKV6 data-dependent lerp for channel c."""
    dt = x.dtype
    base = x + (xprev - x) * p["mu_base"].astype(dt)
    mix = p[f"mu_{c}"].astype(dt) + jnp.tanh(
        base @ p[f"mix_A_{c}"].astype(dt)
    ) @ p[f"mix_B_{c}"].astype(dt)
    return x + (xprev - x) * mix


def _decay_log(p, x, xprev):
    """Per-channel log-decay  lw = -exp(w0 + lora(x))  (negative)."""
    dt = x.dtype
    base = x + (xprev - x) * p["mu_base"].astype(dt)
    wr = p["w0"].astype(jnp.float32) + (
        jnp.tanh(base @ p["w_A"].astype(dt)) @ p["w_B"].astype(dt)
    ).astype(jnp.float32)
    return -jnp.exp(wr)                                   # (B,S,D)


def _group_norm_heads(y, weight, H, eps=64e-5):
    """Per-head layernorm of (B,S,H,dh) flattened output (RWKV ln_x)."""
    B, S, _, dh = y.shape
    y32 = y.astype(jnp.float32)
    mu = y32.mean(-1, keepdims=True)
    var = y32.var(-1, keepdims=True)
    out = (y32 - mu) * jax.lax.rsqrt(var + eps)
    out = out.reshape(B, S, H * dh) * (1.0 + weight.astype(jnp.float32))
    return out


def tmix_apply(cfg, p, x, shift_prev=None, return_state: bool = False):
    """Time-mix over a full sequence (training / prefill)."""
    dt = x.dtype
    B, S, D = x.shape
    _, H, dh = _dims(cfg)
    T = cfg.rwkv_chunk
    while S % T:
        T //= 2
    if shift_prev is None:
        shift_prev = jnp.zeros((B, D), dt)
    xprev = _token_shift(x, shift_prev)

    r = _ddlerp(p, "r", x, xprev) @ p["wr"].astype(dt)
    k = _ddlerp(p, "k", x, xprev) @ p["wk"].astype(dt)
    v = _ddlerp(p, "v", x, xprev) @ p["wv"].astype(dt)
    g = jax.nn.silu(_ddlerp(p, "g", x, xprev) @ p["wg"].astype(dt))
    lw = _decay_log(p, x, xprev)                          # (B,S,D) fp32

    def heads(a):
        return a.reshape(B, S, H, dh)

    r, k, v = heads(r), heads(k), heads(v)
    lw = lw.reshape(B, S, H, dh)

    nc = S // T
    rc = r.reshape(B, nc, T, H, dh)
    kc = k.reshape(B, nc, T, H, dh)
    vc = v.reshape(B, nc, T, H, dh)
    lwc = lw.reshape(B, nc, T, H, dh)
    cw = jnp.cumsum(lwc, axis=2)                          # inclusive
    u = p["u"].astype(jnp.float32)

    mask_strict = jnp.tril(jnp.ones((T, T), bool), k=-1)

    def chunk(state, inp):
        rq, kq, vq, cwq, lwq = inp                        # (B,T,H,dh)
        cw_last = cwq[:, -1]                              # (B,H,dh)
        ecw = cwq - lwq                                   # exclusive cumsum
        # intra-chunk: A[t,i] = Σ_c r_t[c] k_i[c] exp(cw_{t-1,c} - cw_{i,c})
        # for i < t. The pairwise exponent is always <= 0 (cw is decreasing),
        # so the (T, T, dh) exponent tensor is materialized per head — exact
        # and overflow-free. (A low-rank factorization exp(a-b)=exp(a)exp(-b)
        # is NOT safe here: data-dependent decays make exp(-cw_i) unbounded.)
        diff = ecw[:, :, None] - cwq[:, None, :, :]       # (B,T,T,H,dh)
        att = jnp.einsum(
            "bthc,bihc,btihc->bhti",
            rq.astype(jnp.float32),
            kq.astype(jnp.float32),
            jnp.exp(jnp.minimum(diff, 0.0)),
        )
        att = jnp.where(mask_strict[None, None], att, 0.0)
        y_intra = jnp.einsum("bhti,bihd->bthd", att, vq.astype(jnp.float32))
        # diagonal u-bonus
        diag = jnp.einsum(
            "bthc,hc,bthc->bth", rq.astype(jnp.float32), u,
            kq.astype(jnp.float32),
        )
        y_u = diag[..., None] * vq.astype(jnp.float32)
        # inter: y_t += (r_t ⊙ exp(ecw_t)) @ S_prev   (ecw <= 0: safe)
        r_inter = rq.astype(jnp.float32) * jnp.exp(ecw)
        y_inter = jnp.einsum("bthc,bhcd->bthd", r_inter, state)
        # state update:  S' = exp(cw_last) S + Σ_i k_i exp(cw_last - cw_i) v_i
        # (cw_last - cw_i <= 0: safe)
        k_upd = kq.astype(jnp.float32) * jnp.exp(cw_last[:, None] - cwq)
        s_new = jnp.exp(cw_last)[..., None] * state + jnp.einsum(
            "bthc,bthd->bhcd", k_upd, vq.astype(jnp.float32)
        )
        return s_new, y_intra + y_u + y_inter

    state0 = jnp.zeros((B, H, dh, dh), jnp.float32)
    scan_in = tuple(
        jnp.moveaxis(a, 1, 0) for a in (rc, kc, vc, cw, lwc)
    )
    s_final, yc = jax.lax.scan(chunk, state0, scan_in)    # (nc,B,T,H,dh)
    y = jnp.moveaxis(yc, 0, 1).reshape(B, S, H, dh)
    y = _group_norm_heads(y, p["ln_w"], H).astype(dt)
    out = (y * g) @ p["wo"].astype(dt)
    if return_state:
        return out, s_final
    return out


def cmix_apply(cfg, p, x, shift_prev=None) -> jnp.ndarray:
    dt = x.dtype
    B, S, D = x.shape
    if shift_prev is None:
        shift_prev = jnp.zeros((B, D), dt)
    xprev = _token_shift(x, shift_prev)
    xk = x + (xprev - x) * p["mu_k"].astype(dt)
    xr = x + (xprev - x) * p["mu_r"].astype(dt)
    kk = jnp.square(jax.nn.relu(xk @ p["wk"].astype(dt)))
    return jax.nn.sigmoid(xr @ p["wr"].astype(dt)) * (
        kk @ p["wv"].astype(dt)
    )


# --------------------------------------------------------------- decode
def init_cache(cfg, batch: int, dtype) -> RWKVCache:
    D, H, dh = _dims(cfg)
    return RWKVCache(
        shift_tmix=jnp.zeros((batch, D), dtype),
        shift_cmix=jnp.zeros((batch, D), dtype),
        wkv=jnp.zeros((batch, H, dh, dh), jnp.float32),
        index=jnp.zeros((), jnp.int32),
    )


def tmix_decode(cfg, p, x, cache: RWKVCache) -> Tuple[jnp.ndarray, RWKVCache]:
    """x: (B, 1, D) single-token time-mix."""
    dt = x.dtype
    B, _, D = x.shape
    _, H, dh = _dims(cfg)
    xprev = cache.shift_tmix[:, None].astype(dt)
    r = _ddlerp(p, "r", x, xprev) @ p["wr"].astype(dt)
    k = _ddlerp(p, "k", x, xprev) @ p["wk"].astype(dt)
    v = _ddlerp(p, "v", x, xprev) @ p["wv"].astype(dt)
    g = jax.nn.silu(_ddlerp(p, "g", x, xprev) @ p["wg"].astype(dt))
    lw = _decay_log(p, x, xprev)[:, 0].reshape(B, H, dh)
    r = r.reshape(B, H, dh).astype(jnp.float32)
    k = k.reshape(B, H, dh).astype(jnp.float32)
    v = v.reshape(B, H, dh).astype(jnp.float32)
    u = p["u"].astype(jnp.float32)
    s = cache.wkv
    y = jnp.einsum("bhc,bhcd->bhd", r, s) + jnp.einsum(
        "bhc,hc,bhc,bhd->bhd", r, u, k, v
    )
    s_new = jnp.exp(lw)[..., None] * s + jnp.einsum("bhc,bhd->bhcd", k, v)
    y = _group_norm_heads(y[:, None], p["ln_w"], H).astype(dt)
    out = (y * g) @ p["wo"].astype(dt)
    return out, cache._replace(
        shift_tmix=x[:, 0].astype(cache.shift_tmix.dtype),
        wkv=s_new,
        index=cache.index + 1,
    )


def cmix_decode(cfg, p, x, cache: RWKVCache) -> Tuple[jnp.ndarray, RWKVCache]:
    out = cmix_apply(cfg, p, x, shift_prev=cache.shift_cmix.astype(x.dtype))
    return out, cache._replace(shift_cmix=x[:, 0].astype(
        cache.shift_cmix.dtype))
