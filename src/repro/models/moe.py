"""Mixture-of-Experts: top-k routing with fixed expert capacity.

Sort-free deterministic dispatch: tokens pick top-k experts; each (token,
slot) gets a position within its expert via a cumulative one-hot count;
tokens beyond expert capacity are dropped (their combine weight is zeroed) —
GShard semantics. Expert weights are sharded over "model" (expert
parallelism); the token->expert buffer movement lowers to all-to-all-style
collectives under GSPMD.

Shared experts (DeepSeek) run densely over all tokens.

Load-balance auxiliary loss (Switch-style) is returned to the train loss;
the LPT analysis in distributed/partition.py consumes the same per-expert
load counts for placement studies (DESIGN.md §5 crossover).
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import layers
from repro.distributed import sharding as _shard


def moe_init(key, cfg) -> dict:
    D = cfg.d_model
    E, Fe = cfg.n_experts, cfg.d_ff_expert
    ks = jax.random.split(key, 5)
    out_scale = 1.0 / math.sqrt(2 * cfg.n_layers)
    p = {
        "router": layers.dense_init(ks[0], (D, E), scale=0.5),
        "wg": layers.dense_init(ks[1], (E, D, Fe)),
        "wu": layers.dense_init(ks[2], (E, D, Fe)),
        "wo": layers.dense_init(ks[3], (E, Fe, D), scale=out_scale),
    }
    if cfg.n_shared_experts:
        Fs = cfg.n_shared_experts * Fe
        kk = jax.random.split(ks[4], 3)
        p["shared"] = {
            "wg": layers.dense_init(kk[0], (D, Fs)),
            "wu": layers.dense_init(kk[1], (D, Fs)),
            "wo": layers.dense_init(kk[2], (Fs, D), scale=out_scale),
        }
    return p


def moe_apply(cfg, p, x) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) -> (out, aux_loss)."""
    dt = x.dtype
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T = B * S
    xt = x.reshape(T, D)

    logits = (xt.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                    # (T, E)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)            # (T, K)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9
    )                                                          # renormalize

    # Switch-style load-balance loss
    me = probs.mean(0)                                         # (E,)
    one_hot_top1 = jax.nn.one_hot(expert_idx[:, 0], E)
    ce = one_hot_top1.mean(0)
    aux = cfg.router_aux_coef * E * jnp.sum(me * ce)

    # ---- capacity dispatch ------------------------------------------------
    C = int(math.ceil(T * K * cfg.capacity_factor / E))
    C = max(8, -(-C // 8) * 8)
    flat_e = expert_idx.reshape(-1)                            # (T*K,)
    # position of each (token, slot) within its expert: running count
    eo = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)            # (T*K, E)
    pos_in_e = (jnp.cumsum(eo, axis=0) - eo)                   # exclusive
    pos = jnp.take_along_axis(pos_in_e, flat_e[:, None], axis=1)[:, 0]
    keep = pos < C
    gate_keep = jnp.where(
        keep.reshape(T, K), gate_vals.astype(jnp.float32), 0.0
    )

    # scatter tokens into (E, C, D) buffers
    safe_pos = jnp.where(keep, pos, C - 1)
    buf = jnp.zeros((E, C, D), dt)
    src = jnp.repeat(xt, K, axis=0)                            # (T*K, D)
    src = jnp.where(keep[:, None], src, 0)
    buf = buf.at[flat_e, safe_pos].add(src)                    # dup-safe: add

    # expert FFN (E sharded over "model")
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["wg"].astype(dt)))
    u = jnp.einsum("ecd,edf->ecf", buf, p["wu"].astype(dt))
    yb = jnp.einsum("ecf,efd->ecd", g * u, p["wo"].astype(dt))  # (E, C, D)

    # combine: gather back and weight.
    # §Perf iteration 7 (REFUTED, reverted): forcing token-sharding through
    # the dispatch/combine via hints made GSPMD's gather fallbacks worse
    # (33.5s -> 58.4s collective on dbrx train). The identified real fix is
    # an explicit shard_map all-to-all dispatch (MaxText-style) — recorded
    # as the top follow-up in EXPERIMENTS.md §Perf.
    y_tok = yb[flat_e, safe_pos].reshape(T, K, D)
    y = jnp.einsum("tkd,tk->td", y_tok.astype(jnp.float32), gate_keep)
    y = y.astype(dt)

    if cfg.n_shared_experts:
        y = y + layers.mlp_apply(cfg, p["shared"], xt)
    return y.reshape(B, S, D), aux


def expert_load_counts(cfg, p, x) -> jnp.ndarray:
    """Per-expert top-1 token counts (for the LPT placement analysis)."""
    T = x.shape[0] * x.shape[1]
    logits = x.reshape(T, -1).astype(jnp.float32) @ p["router"].astype(
        jnp.float32
    )
    top1 = jnp.argmax(logits, -1)
    return jnp.bincount(top1, length=cfg.n_experts)


# ---------------------------------------------------------------- a2a MoE
def moe_apply_a2a(cfg, p, x, mesh) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Expert-parallel MoE with an explicit shard_map all-to-all exchange.

    The GSPMD gather/scatter dispatch (moe_apply) lowers the expert->token
    combine into a per-layer all-reduce of the full (T·K, D/TP) tensor
    (§Perf iteration 7). This path makes the token<->expert movement
    explicit: tokens are split over the "model" axis, each rank builds one
    send buffer per destination expert-rank, `lax.all_to_all` exchanges
    them, local experts run, and a second all_to_all returns results —
    every token crosses the wire exactly twice, in the compute dtype.

    Ranks with E/TP > 1 local experts evaluate each local expert on the
    whole received buffer and select (overcompute factor E/TP; exact for
    dbrx's 16e/16 ranks — noted in EXPERIMENTS).
    """
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map
    from repro.distributed import sharding as _sh

    dt = x.dtype
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T = B * S
    bd = _sh.batch_axes(mesh)
    M = mesh.shape.get(_sh.TP, 1)
    n_bd = int(np.prod([mesh.shape[a] for a in bd])) if bd else 1
    if M == 1 or E % M or (T // max(n_bd, 1)) % M:
        return moe_apply(cfg, p, x)               # fall back to GSPMD path
    E_loc = E // M
    xt = x.reshape(T, D)

    def f(x_loc, router, wg, wu, wo):
        # x_loc: (T_loc, D) data-sharded, replicated over model
        m = jax.lax.axis_index(_sh.TP)
        T_loc = x_loc.shape[0]
        T2 = T_loc // M
        x_my = jax.lax.dynamic_slice_in_dim(x_loc, m * T2, T2, 0)

        logits = x_my.astype(jnp.float32) @ router.astype(jnp.float32)
        probs = jax.nn.softmax(logits, -1)                     # (T2, E)
        gate_vals, eidx = jax.lax.top_k(probs, K)
        gate_vals = gate_vals / jnp.maximum(
            gate_vals.sum(-1, keepdims=True), 1e-9)
        # load-balance aux (global mean via psum over all axes)
        me_sum = probs.sum(0)
        ce_sum = jax.nn.one_hot(eidx[:, 0], E).sum(0)
        axes_all = tuple(bd) + (_sh.TP,)
        me = jax.lax.psum(me_sum, axes_all) / T
        ce = jax.lax.psum(ce_sum, axes_all) / T
        aux = cfg.router_aux_coef * E * jnp.sum(me * ce)

        flat_e = eidx.reshape(-1)                              # (T2*K,)
        dest = flat_e // E_loc                                 # rank
        e_loc = flat_e % E_loc                                 # local expert
        C2 = int(math.ceil(T2 * K * cfg.capacity_factor / M))
        C2 = max(8, -(-C2 // 8) * 8)
        oh = jax.nn.one_hot(dest, M, dtype=jnp.int32)
        pos = jnp.take_along_axis(
            jnp.cumsum(oh, 0) - oh, dest[:, None], 1)[:, 0]
        keep = pos < C2
        safe_pos = jnp.where(keep, pos, C2 - 1)
        gate_keep = jnp.where(keep.reshape(T2, K),
                              gate_vals.astype(jnp.float32), 0.0)

        src = jnp.repeat(x_my, K, axis=0)
        src = jnp.where(keep[:, None], src, 0)
        send = jnp.zeros((M, C2, D), dt).at[dest, safe_pos].add(src)
        send_e = jnp.zeros((M, C2), jnp.int32).at[dest, safe_pos].max(
            jnp.where(keep, e_loc, 0))
        recv = jax.lax.all_to_all(send, _sh.TP, 0, 0, tiled=False)
        recv_e = jax.lax.all_to_all(send_e, _sh.TP, 0, 0, tiled=False)
        tok = recv.reshape(M * C2, D)

        def one_expert(le):
            g = jax.nn.silu(tok @ wg[le].astype(dt))
            u = tok @ wu[le].astype(dt)
            return (g * u) @ wo[le].astype(dt)

        yb = one_expert(0)
        for le in range(1, E_loc):
            yb = jnp.where(
                (recv_e.reshape(-1) == le)[:, None], one_expert(le), yb)
        back = jax.lax.all_to_all(
            yb.reshape(M, C2, D), _sh.TP, 0, 0, tiled=False)
        y_tok = back[dest, safe_pos].reshape(T2, K, D)
        y_my = jnp.einsum("tkd,tk->td", y_tok.astype(jnp.float32),
                          gate_keep).astype(dt)
        y_full = jax.lax.all_gather(y_my, _sh.TP, axis=0,
                                    tiled=False).reshape(T_loc, D)
        return y_full, aux

    in_specs = (
        P(bd if bd else None, None),
        P(None, None),
        P(_sh.TP, None, None), P(_sh.TP, None, None), P(_sh.TP, None, None),
    )
    out_specs = (P(bd if bd else None, None), P())
    # check_vma=False: y_full is made replicated-over-model by the final
    # all_gather, which the static replication checker cannot infer.
    y, aux = shard_map(f, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, check_vma=False)(
        xt, p["router"], p["wg"], p["wu"], p["wo"])
    y = y.reshape(B, S, D)
    if cfg.n_shared_experts:
        y = y + layers.mlp_apply(cfg, p["shared"], x.reshape(B, S, D))
    return y, aux
