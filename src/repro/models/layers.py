"""Shared neural layers: norms, RoPE, MLPs, embeddings, init helpers.

Parameters are plain nested dicts of jnp arrays (fp32 masters); compute casts
to ``cfg.compute_dtype``. Sharding lives in ``distributed/sharding.py`` as a
parallel tree of PartitionSpecs keyed by the same structure.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro import compat


# ------------------------------------------------------------------- init
def dense_init(key, shape, in_axis: int = -2, scale: float = 1.0,
               dtype=jnp.float32):
    """Truncated-normal fan-in init (matches common LM practice)."""
    fan_in = shape[in_axis]
    std = scale / math.sqrt(fan_in)
    return std * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return jax.random.normal(key, shape, dtype) * 0.02


# ------------------------------------------------------------------- norms
def rms_norm(x, weight, eps: float = 1e-5):
    dt = x.dtype
    # barrier: keeps the fp32 upcast from being fused across the TP
    # all-reduce feeding the norm (§Perf iteration 3; ~2% on zamba2,
    # neutral elsewhere — measured both ways on dbrx)
    x = compat.optimization_barrier(x)
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + weight.astype(jnp.float32))).astype(dt)


def layer_norm(x, weight, bias=None, eps: float = 1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + eps)
    out = out * (1.0 + weight.astype(jnp.float32))
    if bias is not None:
        out = out + bias.astype(jnp.float32)
    return out.astype(dt)


def apply_norm(cfg, x, w):
    if cfg.norm == "layernorm":
        return layer_norm(x, w, eps=cfg.norm_eps)
    return rms_norm(x, w, eps=cfg.norm_eps)


def norm_init(d):
    return jnp.zeros((d,), jnp.float32)


# -------------------------------------------------------------------- RoPE
def rope_freqs(dims: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, dims, 2, jnp.float32) / dims))


def apply_rope(x, positions, theta: float = 10_000.0):
    """x: (..., S, H, dh) with dh even; positions: (..., S)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                       # (dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, dh/2)
    cos = jnp.cos(angles)[..., None, :]                 # (..., S, 1, dh/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq: int, d: int) -> jnp.ndarray:
    """Whisper-style fixed sinusoidal embeddings (S, d)."""
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    inv = jnp.exp(-math.log(10_000.0) * dim / (d // 2 - 1))
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# --------------------------------------------------------------------- MLP
def mlp_init(key, cfg, d_ff: Optional[int] = None) -> dict:
    D = cfg.d_model
    F = d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    if cfg.mlp == "gelu":
        return {
            "wi": dense_init(k1, (D, F)),
            "wo": dense_init(k2, (F, D), scale=1.0 / math.sqrt(
                2 * cfg.n_layers)),
        }
    return {
        "wg": dense_init(k1, (D, F)),
        "wu": dense_init(k2, (D, F)),
        "wo": dense_init(k3, (F, D), scale=1.0 / math.sqrt(2 * cfg.n_layers)),
    }


def mlp_apply(cfg, p, x):
    dt = x.dtype
    if "wi" in p:  # gelu
        h = jax.nn.gelu(x @ p["wi"].astype(dt))
        return h @ p["wo"].astype(dt)
    g = jax.nn.silu(x @ p["wg"].astype(dt))
    u = x @ p["wu"].astype(dt)
    return (g * u) @ p["wo"].astype(dt)


# --------------------------------------------------------------- embedding
def embedding_init(key, cfg) -> dict:
    p = {"tok": embed_init(key, (cfg.vocab, cfg.d_model))}
    return p


def unembed_init(key, cfg) -> Optional[jnp.ndarray]:
    if cfg.tie_embeddings:
        return None
    return dense_init(key, (cfg.d_model, cfg.vocab))


def logits_from_hidden(cfg, params, h):
    """h: (..., D) -> (..., V); fp32 logits for a stable softmax/CE."""
    if cfg.tie_embeddings:
        w = params["embed"]["tok"].astype(h.dtype).T
    else:
        w = params["head"].astype(h.dtype)
    return (h @ w).astype(jnp.float32)
