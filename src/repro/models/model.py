"""Model facade: init / loss / forward / prefill / decode_step.

Decode state is a stacked-per-layer cache pytree driven through lax.scan —
the same depth-independent compile posture as the training forward.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from . import layers, attention, mla, moe, ssm, rwkv, transformer
from .attention import KVCache
from .transformer import init_params, forward, encode


class DecodeState(NamedTuple):
    layer: Any                 # stacked per-layer cache pytree
    shared: Any                # (n_sites, ...) KVCache stack (zamba2) or None
    cross: Any                 # (enc_out, stacked cross-KV) (whisper) or None
    step: jnp.ndarray          # int32 sequence cursor: scalar (all rows in
    #                            lockstep) or (B,) per-row (slot-swap
    #                            continuous batching — see serve/engine.py)


# ------------------------------------------------------------ cache builders
def _layer_cache(cfg, batch: int, max_seq: int, dtype):
    """One layer's decode cache for this config's mixer."""
    if cfg.mixer == "attn":
        if cfg.mla:
            return mla.init_cache(cfg, batch, max_seq, dtype)
        return attention.init_cache(cfg, batch, max_seq, dtype)
    if cfg.mixer == "mamba2":
        return ssm.init_cache(cfg, batch, dtype)
    if cfg.mixer == "rwkv6":
        return rwkv.init_cache(cfg, batch, dtype)
    raise ValueError(cfg.mixer)


def _stack(n, tree):
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a, (n,) + a.shape), tree
    )


def init_decode_state(cfg, batch: int, max_seq: int,
                      dtype=jnp.bfloat16,
                      per_row: bool = False) -> DecodeState:
    """Fresh decode cache pool. ``per_row=True`` makes ``step`` a (B,)
    vector so every row keeps its own sequence position (slot-swap
    serving); per-layer scalar ``index`` cursors are then ignored."""
    layer = _stack(cfg.n_layers, _layer_cache(cfg, batch, max_seq, dtype))
    shared = None
    if cfg.shared_attn_every > 0:
        shared = _stack(
            cfg.attn_sites,
            attention.init_cache(cfg, batch, max_seq, dtype),
        )
    cross = None
    if cfg.enc_dec:
        dt = jnp.dtype(cfg.compute_dtype)
        Hkv, dh = cfg.n_kv_heads, cfg.head_dim
        cross = (
            jnp.zeros((batch, cfg.enc_seq, cfg.d_model), dt),   # enc_out
            jnp.zeros((cfg.n_layers, batch, cfg.enc_seq, Hkv, dh), dt),  # K
            jnp.zeros((cfg.n_layers, batch, cfg.enc_seq, Hkv, dh), dt),  # V
        )
    step = (jnp.zeros((batch,), jnp.int32) if per_row
            else jnp.zeros((), jnp.int32))
    return DecodeState(layer=layer, shared=shared, cross=cross, step=step)


# ----------------------------------------------------------------- decode
def _mixer_decode(cfg, bp, x, cache, positions=None):
    if cfg.mixer == "attn":
        if cfg.mla:
            return mla.mla_decode(cfg, bp["mla"], x, cache,
                                  positions=positions)
        return attention.attn_decode(cfg, bp["attn"], x, cache,
                                     use_rope=cfg.use_rope,
                                     positions=positions)
    # recurrent mixers carry per-row state and no positional math — the
    # same decode serves lockstep and per-row cursors
    if cfg.mixer == "mamba2":
        return ssm.ssm_decode(cfg, bp["ssm"], x, cache)
    if cfg.mixer == "rwkv6":
        return rwkv.tmix_decode(cfg, bp["tmix"], x, cache)
    raise ValueError(cfg.mixer)


def _channel_decode(cfg, bp, x, cache, layer_idx):
    """Channel mixer during decode; rwkv cmix carries shift state."""
    if cfg.mlp == "rwkv6_cmix":
        return rwkv.cmix_decode(cfg, bp["cmix"], x, cache)
    out, _ = transformer._apply_channel(cfg, bp, x, layer_idx)
    return out, cache


def _cross_decode(cfg, bp, x, k, v):
    """Cross-attention against precomputed encoder K/V (whisper decode)."""
    import math

    dt = x.dtype
    B = x.shape[0]
    H, Hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    p = bp["xattn"]
    q = (x @ p["wq"].astype(dt)).reshape(B, 1, H, dh)
    kk = attention._repeat_kv(k.astype(dt), cfg.q_per_kv)
    vv = attention._repeat_kv(v.astype(dt), cfg.q_per_kv)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kk) / math.sqrt(dh)
    probs = jax.nn.softmax(s.astype(jnp.float32), -1).astype(dt)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, vv)
    return out.reshape(B, 1, H * dh) @ p["wo"].astype(dt)


def decode_step(cfg, params, token: jnp.ndarray,
                state: DecodeState) -> Tuple[jnp.ndarray, DecodeState]:
    """One decode step. token: (B, 1) int32 (or (B, 1, D) embeds for vlm
    image-free steps are not needed: decode always consumes token ids)."""
    dt = jnp.dtype(cfg.compute_dtype)
    per_row = state.step.ndim == 1
    positions = state.step if per_row else None
    x = params["embed"]["tok"].astype(dt)[token]            # (B,1,D)
    if cfg.enc_dec:
        pos_emb = layers.sinusoidal_positions(cfg.max_seq, cfg.d_model)
        if per_row:
            x = x + pos_emb[state.step][:, None].astype(dt)
        else:
            x = x + jax.lax.dynamic_slice_in_dim(
                pos_emb, state.step, 1, axis=0
            ).astype(dt)[None]

    L = cfg.n_layers
    flags = None
    site_idx = None
    if cfg.shared_attn_every > 0:
        idxs = jnp.arange(L)
        flags = (idxs % cfg.shared_attn_every) == (cfg.shared_attn_every - 1)
        site_idx = jnp.cumsum(flags) - 1                    # (L,)

    dense_mlp = params.get("dense_mlp")
    cross = state.cross

    def body(carry, inp):
        x, shared_caches = carry
        if flags is not None:
            bp, cache_l, li, flag, site = inp
        else:
            bp, cache_l, li = inp
        if dense_mlp is not None:
            bp = dict(bp, dense_mlp=dense_mlp)
        h = layers.apply_norm(cfg, x, bp["norm1"])
        h, cache_mix = _mixer_decode(cfg, bp, h, _mix_cache(cfg, cache_l),
                                     positions)
        x = x + h
        if flags is not None:
            scfg = cfg.replace(mixer="attn")

            def with_attn(op):
                x, sc = op
                cache_s = jax.tree.map(lambda a: a[site], sc)
                if not per_row:
                    # all sites share the same write index = step
                    cache_s = cache_s._replace(index=state.step)
                h2, cache_s = attention.attn_decode(
                    scfg, params["shared_attn"],
                    layers.apply_norm(cfg, x, params["shared_norm"]),
                    cache_s, use_rope=cfg.use_rope, positions=positions,
                )
                sc = jax.tree.map(
                    lambda full, new: jax.lax.dynamic_update_index_in_dim(
                        full, new, site, 0
                    ),
                    sc, cache_s,
                )
                return x + h2, sc

            x, shared_caches = jax.lax.cond(
                flag, with_attn, lambda op: op, (x, shared_caches)
            )
        if cross is not None:
            enc_out, ck, cv = cross
            x = x + _cross_decode(
                cfg, bp, layers.apply_norm(cfg, x, bp["norm_x"]),
                ck[li], cv[li],
            )
        h = layers.apply_norm(cfg, x, bp["norm2"])
        h, cache_ch = _channel_decode(
            cfg, bp, h, _mix_cache(cfg, cache_l), li
        )
        x = x + h
        new_cache = _merge_cache(cfg, cache_l, cache_mix, cache_ch)
        return (x, shared_caches), new_cache

    if not cfg.scan_layers:
        carry = (x, state.shared)
        new_layer = []
        for i in range(L):
            inp = [jax.tree.map(lambda a: a[i], params["blocks"]),
                   jax.tree.map(lambda a: a[i], state.layer),
                   jnp.asarray(i)]
            if flags is not None:
                inp += [flags[i], site_idx[i]]
            carry, nc = body(carry, tuple(inp))
            new_layer.append(nc)
        x, shared_new = carry
        layer_new = jax.tree.map(lambda *xs: jnp.stack(xs), *new_layer)
    else:
        xs = [params["blocks"], state.layer, jnp.arange(L)]
        if flags is not None:
            xs += [flags, site_idx]
        (x, shared_new), layer_new = jax.lax.scan(
            body, (x, state.shared), tuple(xs)
        )
    x = layers.apply_norm(cfg, x, params["final_norm"])
    logits = layers.logits_from_hidden(cfg, params, x)
    return logits, DecodeState(
        layer=layer_new, shared=shared_new, cross=state.cross,
        step=state.step + 1,
    )


def _mix_cache(cfg, cache_l):
    """Cache handed to the mixer/channel: rwkv shares one cache struct."""
    return cache_l


def _merge_cache(cfg, old, after_mix, after_channel):
    """rwkv: tmix updates (shift_tmix, wkv), cmix updates shift_cmix."""
    if cfg.mixer == "rwkv6":
        return after_mix._replace(shift_cmix=after_channel.shift_cmix)
    return after_mix


# ----------------------------------------------------------------- prefill
def write_slot(cfg, pool: DecodeState, fresh: DecodeState,
               slot) -> DecodeState:
    """Scatter a batch-1 decode state into row ``slot`` of a per-row pool.

    The slot-swap primitive of continuous batching: the entire cache row
    (K/V lines, recurrent state, conv buffers) is overwritten, so whatever
    a previous occupant left behind is gone, and ``pool.step[slot]`` is
    set to the new request's prompt length. Per-layer scalar ``index``
    cursors (rank < 2 leaves) are batch-free and stay untouched — the
    per-row ``step`` vector is the only cursor per-row decode reads.
    """
    if pool.cross is not None:
        raise NotImplementedError(
            "slot-swap prefill does not support encoder-decoder states"
        )

    def _row(p, f):
        if p.ndim < 2:                       # (L,)/(n_sites,) index cursors
            return p
        return jax.lax.dynamic_update_index_in_dim(
            p, jax.lax.squeeze(f, (1,)), slot, 1
        )

    layer = jax.tree.map(_row, pool.layer, fresh.layer)
    shared = (jax.tree.map(_row, pool.shared, fresh.shared)
              if pool.shared is not None else None)
    step = pool.step.at[slot].set(fresh.step.astype(pool.step.dtype))
    return DecodeState(layer=layer, shared=shared, cross=None, step=step)


def prefill(cfg, params, tokens, max_seq: int,
            vision_embeds=None, audio_frames=None,
            state: Optional[DecodeState] = None, slot=None,
            ) -> Tuple[jnp.ndarray, DecodeState]:
    """Run the full prompt, returning last-position logits + decode state.

    Attention caches are filled with the prompt's K/V; recurrent mixers keep
    their end-of-prompt state. Bucketed serving calls this once per batch;
    with ``state``/``slot`` given, ``tokens`` must be (1, S) and the fresh
    request state is scattered into row ``slot`` of the existing per-row
    ``state`` pool (mid-decode slot swap), returning the updated pool.
    """
    dt = jnp.dtype(cfg.compute_dtype)
    B = tokens.shape[0]
    x = params["embed"]["tok"].astype(dt)[tokens]
    if vision_embeds is not None:
        x = jnp.concatenate([vision_embeds.astype(dt), x], axis=1)
    S = x.shape[1]
    positions = jnp.arange(S)
    init_state = init_decode_state(cfg, B, max_seq, dt)
    enc_out = None
    cross = None
    if cfg.enc_dec:
        enc_out = encode(cfg, params, audio_frames)
        x = x + layers.sinusoidal_positions(S, cfg.d_model).astype(dt)[None]
        # precompute cross K/V per layer
        Hkv, dh = cfg.n_kv_heads, cfg.head_dim

        def xkv(bp):
            k = (enc_out @ bp["xattn"]["wk"].astype(dt)).reshape(
                B, cfg.enc_seq, Hkv, dh)
            v = (enc_out @ bp["xattn"]["wv"].astype(dt)).reshape(
                B, cfg.enc_seq, Hkv, dh)
            return k, v

        ck, cv = jax.vmap(xkv)(params["blocks"])
        cross = (enc_out, ck, cv)

    L = cfg.n_layers
    flags = None
    if cfg.shared_attn_every > 0:
        idxs = jnp.arange(L)
        flags = (idxs % cfg.shared_attn_every) == (cfg.shared_attn_every - 1)
        site_idx = jnp.cumsum(flags) - 1

    def fill_attn(p_attn, x_norm, cache):
        """Compute prompt K/V, write into cache[:, :S]."""
        k = (x_norm @ p_attn["wk"].astype(dt)).reshape(
            B, S, cfg.n_kv_heads, cfg.head_dim)
        v = (x_norm @ p_attn["wv"].astype(dt)).reshape(
            B, S, cfg.n_kv_heads, cfg.head_dim)
        if cfg.use_rope:
            k = layers.apply_rope(k, positions[None], cfg.rope_theta)
        kc = jax.lax.dynamic_update_slice(
            cache.k, k.astype(cache.k.dtype), (0, 0, 0, 0))
        vc = jax.lax.dynamic_update_slice(
            cache.v, v.astype(cache.v.dtype), (0, 0, 0, 0))
        return cache._replace(k=kc, v=vc, index=jnp.asarray(S, jnp.int32))

    def body(carry, inp):
        x, shared_caches = carry
        if flags is not None:
            bp, cache_l, li, flag, site = inp
        else:
            bp, cache_l, li = inp
        if "dense_mlp" in params:
            bp = dict(bp, dense_mlp=params["dense_mlp"])
        h_in = layers.apply_norm(cfg, x, bp["norm1"])
        if cfg.mixer == "attn":
            if cfg.mla:
                h = mla.mla_apply(cfg, bp["mla"], h_in, positions)
                c_kv = layers.rms_norm(
                    h_in @ bp["mla"]["w_dkv"].astype(dt),
                    bp["mla"]["kv_norm"], cfg.norm_eps)
                k_rope = layers.apply_rope(
                    (h_in @ bp["mla"]["w_krope"].astype(dt))[:, :, None],
                    positions[None], cfg.rope_theta)[:, :, 0]
                new_cache = cache_l._replace(
                    c_kv=jax.lax.dynamic_update_slice(
                        cache_l.c_kv, c_kv.astype(cache_l.c_kv.dtype),
                        (0, 0, 0)),
                    k_rope=jax.lax.dynamic_update_slice(
                        cache_l.k_rope, k_rope.astype(
                            cache_l.k_rope.dtype), (0, 0, 0)),
                    index=jnp.asarray(S, jnp.int32),
                )
            else:
                h = attention.attn_apply(cfg, bp["attn"], h_in, positions,
                                         use_rope=cfg.use_rope)
                new_cache = fill_attn(bp["attn"], h_in, cache_l)
        elif cfg.mixer == "mamba2":
            h, new_cache = ssm.ssm_apply(cfg, bp["ssm"], h_in,
                                         return_cache=True)
        elif cfg.mixer == "rwkv6":
            h, wkv_state = rwkv.tmix_apply(cfg, bp["tmix"], h_in,
                                           return_state=True)
            new_cache = cache_l._replace(
                shift_tmix=h_in[:, -1].astype(cache_l.shift_tmix.dtype),
                wkv=wkv_state, index=jnp.asarray(S, jnp.int32))
        x = x + h
        if flags is not None:
            scfg = cfg.replace(mixer="attn")

            def with_attn(op):
                x, sc = op
                xn = layers.apply_norm(cfg, x, params["shared_norm"])
                h2 = attention.attn_apply(
                    scfg, params["shared_attn"], xn, positions,
                    use_rope=cfg.use_rope)
                cache_s = jax.tree.map(lambda a: a[site], sc)
                cache_s = fill_attn(params["shared_attn"], xn, cache_s)
                sc = jax.tree.map(
                    lambda full, new: jax.lax.dynamic_update_index_in_dim(
                        full, new, site, 0),
                    sc, cache_s)
                return x + h2, sc

            x, shared_caches = jax.lax.cond(
                flag, with_attn, lambda op: op, (x, shared_caches))
        if cross is not None:
            x = x + attention.attn_apply(
                cfg, bp["xattn"], layers.apply_norm(cfg, x, bp["norm_x"]),
                positions, causal=False, kv_source=enc_out, use_rope=False)
        h_in2 = layers.apply_norm(cfg, x, bp["norm2"])
        if cfg.mlp == "rwkv6_cmix":
            h2 = rwkv.cmix_apply(cfg, bp["cmix"], h_in2)
            new_cache = new_cache._replace(
                shift_cmix=h_in2[:, -1].astype(new_cache.shift_cmix.dtype))
        else:
            h2, _ = transformer._apply_channel(cfg, bp, h_in2, li)
        return (x + h2, shared_caches), new_cache

    xs = [params["blocks"], init_state.layer, jnp.arange(L)]
    if flags is not None:
        xs += [flags, site_idx]
    (x, shared_new), layer_new = jax.lax.scan(
        body, (x, init_state.shared), tuple(xs))
    x = layers.apply_norm(cfg, x, params["final_norm"])
    logits = layers.logits_from_hidden(cfg, params, x[:, -1:])
    fresh = DecodeState(
        layer=layer_new, shared=shared_new, cross=cross,
        step=jnp.asarray(S, jnp.int32),
    )
    if state is None:
        return logits, fresh
    if B != 1:
        raise ValueError(f"slot prefill expects a (1, S) prompt; got B={B}")
    return logits, write_slot(cfg, state, fresh, slot)
