"""Model configuration covering all 10 assigned architectures.

One dataclass, family-specific fields; every arch in configs/ instantiates
this. ``reduced()`` yields the CPU smoke-test variant of the same family.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | ssm | hybrid | moe | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: Optional[int] = None    # default d_model // n_heads

    # token mixer: "attn" everywhere except ssm/hybrid families
    mixer: str = "attn"             # attn | rwkv6 | mamba2
    # hybrid (zamba2): shared attention block applied every k mamba layers
    shared_attn_every: int = 0      # 0 = no shared attention

    # channel mixer
    mlp: str = "swiglu"             # swiglu | gelu | moe | rwkv6_cmix | none

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    moe_impl: str = "gspmd"         # "gspmd" | "a2a" (shard_map all-to-all)
    first_dense_layers: int = 0     # deepseek: layer 0 is dense

    # MLA (deepseek)
    mla: bool = False
    kv_lora: int = 0
    qk_rope_dims: int = 64
    qk_nope_dims: int = 128
    v_head_dim: int = 128

    # SSM (mamba2)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_groups: int = 1

    # enc-dec (whisper)
    enc_dec: bool = False
    n_enc_layers: int = 0
    enc_seq: int = 1500             # whisper: 30s audio -> 1500 frames

    # modality frontend stub
    frontend: str = "none"          # none | audio | vision
    n_vision_tokens: int = 576      # llava base-res image tokens

    # misc
    norm: str = "rmsnorm"           # rmsnorm | layernorm
    use_rope: bool = True
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    max_seq: int = 131_072
    sliding_window: int = 0         # 0 = full attention

    # execution
    train_parallelism: str = "tp"   # "tp" (TP over model axis) | "fsdp"
    compute_dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat: bool = True
    scan_layers: bool = True
    attn_chunk_q: int = 1024
    attn_chunk_kv: int = 1024
    rwkv_chunk: int = 32   # (B,T,T,H,dh) intra tensor must fit HBM
    ssd_chunk: int = 128

    # ------------------------------------------------------------- derived
    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head else self.d_model // self.n_heads

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // max(1, self.n_kv_heads)

    @property
    def d_inner_ssm(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner_ssm // self.ssm_head_dim

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch decode at 500k context? (ssm / linear-attn / hybrid)"""
        return self.mixer in ("rwkv6", "mamba2")

    @property
    def attn_sites(self) -> int:
        """Number of (shared) attention applications for hybrids."""
        if self.shared_attn_every <= 0:
            return 0
        return self.n_layers // self.shared_attn_every

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------- parameter accounting
    def param_count(self) -> int:
        """Approximate parameter count (used for 6ND roofline and reports)."""
        D, F, V, L = self.d_model, self.d_ff, self.vocab, self.n_layers
        H, Hkv, dh = self.n_heads, self.n_kv_heads, self.head_dim
        emb = V * D * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.mixer == "attn":
            per_layer += D * H * dh + 2 * D * Hkv * dh + H * dh * D
        elif self.mixer == "mamba2":
            di = self.d_inner_ssm
            conv_dim = di + 2 * self.ssm_groups * self.ssm_state
            per_layer += D * (2 * di + 2 * self.ssm_groups * self.ssm_state
                              + self.n_ssm_heads)
            per_layer += conv_dim * self.ssm_conv + di * D
        elif self.mixer == "rwkv6":
            per_layer += 4 * D * D + D * D  # r,k,v,g,o projections
            per_layer += 6 * D * 64         # token-shift / decay loras (approx)
        if self.mla:
            per_layer = D * (self.kv_lora + self.qk_rope_dims)
            per_layer += self.kv_lora * H * (self.qk_nope_dims
                                             + self.v_head_dim)
            per_layer += D * H * (self.qk_nope_dims + self.qk_rope_dims)
            per_layer += H * self.v_head_dim * D
        if self.mlp == "swiglu":
            per_layer += 3 * D * F
        elif self.mlp == "gelu":
            per_layer += 2 * D * F
        elif self.mlp == "moe":
            fe = self.d_ff_expert
            per_layer += self.n_experts * 3 * D * fe + D * self.n_experts
            per_layer += self.n_shared_experts * 3 * D * fe
        if self.shared_attn_every > 0:
            shared = D * H * dh * 2 + 2 * D * Hkv * dh  # q,o + k,v
        else:
            shared = 0
        enc = 0
        if self.enc_dec:
            enc = self.n_enc_layers * (4 * D * D + 2 * D * F)
            per_layer += 2 * D * D + D * D + D * D  # cross-attn q,k,v,o
        return emb + L * per_layer + shared + enc

    def active_param_count(self) -> int:
        """Params touched per token (MoE: routed top-k + shared only)."""
        if self.mlp != "moe":
            return self.param_count()
        full = self.param_count()
        fe = self.d_ff_expert
        all_experts = self.n_layers * self.n_experts * 3 * self.d_model * fe
        active = self.n_layers * (
            (self.top_k + self.n_shared_experts) * 3 * self.d_model * fe
        )
        return full - all_experts + active
