"""Process-global metrics: counters, gauges, log-scale histograms.

The numeric companion to ``obs.trace``: spans answer "when / how long was
this one call", metrics aggregate across calls — request counts, tokens/s,
step-time percentiles. Histograms use logarithmic buckets so one instrument
covers microseconds to minutes with bounded memory and ~4% relative
resolution on the reported p50/p95/p99.

Dependency-free (stdlib only). JSON export shape::

    {"counters": {name: value},
     "gauges":   {name: value},
     "histograms": {name: {count, sum, min, max, mean, p50, p95, p99}}}
"""
from __future__ import annotations

import json
import math
import os
import threading
from typing import Dict, List, Optional

# log-scale bucket layout: bucket i covers [BASE**i, BASE**(i+1))
_BASE = 1.08
_LOG_BASE = math.log(_BASE)
# value range 1e-9 .. 1e9 (seconds-scale friendly); clamped outside
_MIN_EXP = math.floor(math.log(1e-9) / _LOG_BASE)
_MAX_EXP = math.ceil(math.log(1e9) / _LOG_BASE)
_N_BUCKETS = _MAX_EXP - _MIN_EXP + 1


class Counter:
    def __init__(self):
        self._v = 0.0
        self._lock = threading.Lock()

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self._v += n

    @property
    def value(self) -> float:
        return self._v


class Gauge:
    def __init__(self):
        self._v = 0.0

    def set(self, v: float) -> None:
        self._v = float(v)

    @property
    def value(self) -> float:
        return self._v


class Histogram:
    """Log-bucketed histogram of positive values (p50/p95/p99 summaries).

    Non-positive observations land in a dedicated underflow bucket and are
    reported through min/count but not the percentiles.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._buckets: Dict[int, int] = {}
        self.count = 0
        self.sum = 0.0
        self.min: Optional[float] = None
        self.max: Optional[float] = None

    @staticmethod
    def _bucket_of(v: float) -> int:
        if v <= 0:
            return _MIN_EXP - 1                       # underflow bucket
        i = math.floor(math.log(v) / _LOG_BASE)
        return max(_MIN_EXP, min(_MAX_EXP, i))

    def observe(self, v: float) -> None:
        v = float(v)
        with self._lock:
            self.count += 1
            self.sum += v
            self.min = v if self.min is None else min(self.min, v)
            self.max = v if self.max is None else max(self.max, v)
            b = self._bucket_of(v)
            self._buckets[b] = self._buckets.get(b, 0) + 1

    def percentile(self, q: float) -> float:
        """Approximate q-quantile (q in [0, 1]) from the bucket counts."""
        with self._lock:
            if self.count == 0:
                return float("nan")
            target = q * self.count
            seen = 0.0
            for b in sorted(self._buckets):
                seen += self._buckets[b]
                if seen >= target:
                    if b < _MIN_EXP:                  # underflow bucket
                        return self.min if self.min is not None else 0.0
                    # geometric midpoint of the bucket, clamped to observed
                    mid = math.exp((b + 0.5) * _LOG_BASE)
                    lo = self.min if self.min is not None else mid
                    hi = self.max if self.max is not None else mid
                    return min(max(mid, lo), hi)
            return self.max if self.max is not None else float("nan")

    def summary(self) -> Dict[str, float]:
        return {
            "count": self.count,
            "sum": self.sum,
            "min": self.min if self.min is not None else float("nan"),
            "max": self.max if self.max is not None else float("nan"),
            "mean": self.sum / self.count if self.count else float("nan"),
            "p50": self.percentile(0.50),
            "p95": self.percentile(0.95),
            "p99": self.percentile(0.99),
        }

    def _merge_summary(self, s: Dict[str, float]) -> None:
        """Coarse merge of an exported summary (cross-process ingest):
        count/sum/min/max merge exactly; the midpoint stands in for the
        child's percentile mass."""
        with self._lock:
            n = int(s.get("count", 0))
            if n == 0:
                return
            self.count += n
            self.sum += s.get("sum", 0.0)
            for k, pick in (("min", min), ("max", max)):
                v = s.get(k)
                if v is not None and not math.isnan(v):
                    cur = getattr(self, k)
                    setattr(self, k, v if cur is None else pick(cur, v))
            mid = s.get("p50", s.get("mean", 0.0))
            b = self._bucket_of(mid if mid and not math.isnan(mid) else 0.0)
            self._buckets[b] = self._buckets.get(b, 0) + n


class Registry:
    """Name -> instrument map; instruments are created on first use."""

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}

    def _get(self, table, name, factory):
        with self._lock:
            inst = table.get(name)
            if inst is None:
                inst = table[name] = factory()
            return inst

    def counter(self, name: str) -> Counter:
        return self._get(self._counters, name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(self._gauges, name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(self._histograms, name, Histogram)

    # ----------------------------------------------------------- exports
    def to_dict(self) -> Dict[str, Dict]:
        with self._lock:
            return {
                "counters": {k: c.value for k, c in self._counters.items()},
                "gauges": {k: g.value for k, g in self._gauges.items()},
                "histograms": {
                    k: h.summary() for k, h in self._histograms.items()
                },
            }

    def save_json(self, path: str) -> None:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.to_dict(), f, indent=1, default=float)

    def merge(self, exported: Dict[str, Dict]) -> None:
        """Fold another registry's ``to_dict()`` output into this one."""
        for k, v in exported.get("counters", {}).items():
            self.counter(k).inc(v)
        for k, v in exported.get("gauges", {}).items():
            self.gauge(k).set(v)
        for k, s in exported.get("histograms", {}).items():
            self.histogram(k)._merge_summary(s)

    def reset(self) -> None:
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._histograms.clear()

    def names(self) -> List[str]:
        with self._lock:
            return sorted(
                set(self._counters) | set(self._gauges)
                | set(self._histograms)
            )


_REGISTRY = Registry()


def get_registry() -> Registry:
    return _REGISTRY


def counter(name: str) -> Counter:
    return _REGISTRY.counter(name)


def gauge(name: str) -> Gauge:
    return _REGISTRY.gauge(name)


def histogram(name: str) -> Histogram:
    return _REGISTRY.histogram(name)


def export() -> Dict[str, Dict]:
    return _REGISTRY.to_dict()


def save_json(path: str) -> None:
    _REGISTRY.save_json(path)


def reset() -> None:
    _REGISTRY.reset()
