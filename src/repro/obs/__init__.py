"""Unified observability: tracing, metrics, shared timer, reconciliation.

  trace      nestable spans -> Chrome-trace/Perfetto JSON
  metrics    process-global counters / gauges / log-scale histograms
  timing     the one benchmark timer (warmup + block_until_ready in one place)
  reconcile  planner predicted-vs-measured phase reconciliation

``trace``/``metrics``/``timing`` are dependency-free (stdlib; jax touched
lazily). ``reconcile`` pulls in core/distributed, so it is loaded lazily to
keep ``repro.obs`` importable from anywhere in the stack without cycles.
"""
from . import metrics, timing, trace
from .metrics import counter, gauge, histogram
from .timing import timeit
from .trace import span

__all__ = [
    "trace",
    "metrics",
    "timing",
    "reconcile",
    "span",
    "timeit",
    "counter",
    "gauge",
    "histogram",
]


def __getattr__(name):
    if name == "reconcile":
        import importlib

        mod = importlib.import_module(".reconcile", __name__)
        globals()["reconcile"] = mod
        return mod
    raise AttributeError(f"module 'repro.obs' has no attribute {name!r}")
