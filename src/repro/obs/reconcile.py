"""Planner predicted-vs-measured reconciliation.

``core/plan.py`` prices every execution strategy with a three-term model
(init = HBM memset, compute = point work x imbalance, comm = collectives)
— this module closes the loop: it *measures* the same three terms on a live
mesh and joins them against the prediction, per strategy and per term, with
relative errors. Every future perf PR gets a phase-level baseline instead
of one opaque wall-clock number.

Measurement protocol (differential timing — host wall clocks cannot see
inside one jitted program):

  init_s     jitted memset of the strategy's per-device grid buffer
  nocomm     the strategy compiled with collectives stripped
             (``build_*(..., collectives=False)``; DD has none to strip)
  full       the production strategy

  measured.init    = t(init)
  measured.compute = max(t(nocomm) - t(init), 0)
  measured.comm    = max(t(full) - t(nocomm), 0)
  measured.total   = t(full)

All timings flow through ``obs.timing.timeit`` (shared warmup +
block_until_ready) and therefore appear as spans in the Chrome trace.

Probe registry
--------------

Every probed strategy is one ``StrategyProbe`` entry in ``PROBED`` — a
declarative spec binding the strategy's prepare/build pair from
``distributed/stkde_dist.py`` to the probe protocol above. The registry is
the single source of truth for what can be reconciled: ``run``'s default
strategy list, ``measure_strategy``'s error message, and
``plan.calibrate_host``'s row filter are all derived from its keys.
Registering an eighth strategy = adding ``collectives=False`` support to
its builder in ``stkde_dist.py`` + one ``PROBED`` entry here (see
docs/observability.md).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from . import timing, trace

TERMS = ("init_s", "compute_s", "comm_s", "total_s")


def _default_hw():
    """V5E on TPU backends; calibrated host constants on CPU (so the
    smoke-run relative errors are about calibration, not CPU != TPU)."""
    from repro.core import plan

    return plan.default_hw()


def _sd():
    """Lazy import: keep ``repro.obs`` importable without pulling in jax."""
    from repro.distributed import stkde_dist

    return stkde_dist


def _axes_all(mesh) -> Tuple[str, ...]:
    return tuple(mesh.axis_names)


def _axes_workers(mesh) -> Tuple[str, ...]:
    """The worker (grid-sharding) axes: the *last two* mesh axes.

    On a 3-axis mesh the leading axis stays replicated (2-D strategies)
    or serves as the replication axis (hybrid)."""
    return tuple(mesh.axis_names)[-2:]


def _axes_xyz(mesh) -> Tuple[str, ...]:
    names = tuple(mesh.axis_names)
    if len(names) != 3:
        raise ValueError(
            f"pd_xyt probe needs a 3-axis (x, y, t) mesh, got {names}")
    return names


def _rep_axis(mesh, axes) -> str:
    """First mesh axis not claimed by the worker grid (hybrid's rep)."""
    rest = [a for a in mesh.axis_names if a not in axes]
    if not rest:
        raise ValueError(
            f"hybrid probe needs a rep axis outside the worker axes {axes};"
            f" mesh has only {tuple(mesh.axis_names)}")
    return rest[0]


def _worker_dims(dom, mesh, axes) -> Tuple[int, int]:
    A, B = (mesh.shape[a] for a in axes)
    return _sd()._device_grid_dims(dom, A, B)


@dataclasses.dataclass(frozen=True)
class StrategyProbe:
    """Declarative phase-probe spec for one strategy.

    prepare(pts, dom, mesh, axes, cap) -> (args, ctx)
        Host-side bucketing/layout. ``args`` is the positional argument
        tuple for the built callables; ``ctx`` carries point-dependent
        *static* parameters the builders need to compile (e.g. DD-LPT's
        tile/k/cap/ntiles) — empty for most strategies.
    build(dom, mesh, axes, n, ctx) -> fn
        The production (collectives-on) jitted strategy.
    build_nocomm(dom, mesh, axes, n, ctx) -> fn, or None
        Same compute with collectives stripped. ``None`` declares the
        strategy communication-free (DD): the full build is reused and
        measured comm is exactly 0.
    local_shape(dom, mesh, axes, ctx) -> tuple
        Per-device grid buffer shape — the memset probe for ``init_s``.
    default_axes(mesh) -> axes
        The mesh axes the strategy spans when the caller passes none.
    plan_shape(mesh, axes) -> mesh_shape
        The shape handed to ``plan.estimate`` so the prediction prices
        the same decomposition the probe measures ((A, B), (R, A, B), or
        pd_xyt's (X, Y, T)).
    """

    prepare: Callable
    build: Callable
    build_nocomm: Optional[Callable]
    local_shape: Callable
    default_axes: Callable
    plan_shape: Callable


def _probe_dr() -> StrategyProbe:
    return StrategyProbe(
        prepare=lambda pts, dom, mesh, axes, cap:
            ((_sd().prepare_dr(pts, dom, mesh, axes),), {}),
        build=lambda dom, mesh, axes, n, ctx:
            _sd().build_dr(dom, mesh, axes, n),
        build_nocomm=lambda dom, mesh, axes, n, ctx:
            _sd().build_dr(dom, mesh, axes, n, collectives=False),
        local_shape=lambda dom, mesh, axes, ctx: dom.grid_shape,
        default_axes=_axes_all,
        plan_shape=lambda mesh, axes:
            (1, int(np.prod([mesh.shape[a] for a in axes]))),
    )


def _probe_dd() -> StrategyProbe:
    return StrategyProbe(
        prepare=lambda pts, dom, mesh, axes, cap:
            (_sd().prepare_dd(pts, dom, mesh, axes, cap=cap), {}),
        build=lambda dom, mesh, axes, n, ctx:
            _sd().build_dd(dom, mesh, axes, n),
        build_nocomm=None,                  # DD is communication-free
        local_shape=lambda dom, mesh, axes, ctx:
            _worker_dims(dom, mesh, axes) + (dom.Gt,),
        default_axes=_axes_workers,
        plan_shape=lambda mesh, axes: tuple(mesh.shape[a] for a in axes),
    )


def _probe_pd() -> StrategyProbe:
    def shape(dom, mesh, axes, ctx):
        gx, gy = _worker_dims(dom, mesh, axes)
        return (gx + 2 * dom.Hs, gy + 2 * dom.Hs, dom.Gt)

    return StrategyProbe(
        prepare=lambda pts, dom, mesh, axes, cap:
            (_sd().prepare_pd(pts, dom, mesh, axes, cap=cap), {}),
        build=lambda dom, mesh, axes, n, ctx:
            _sd().build_pd(dom, mesh, axes, n),
        build_nocomm=lambda dom, mesh, axes, n, ctx:
            _sd().build_pd(dom, mesh, axes, n, collectives=False),
        local_shape=shape,
        default_axes=_axes_workers,
        plan_shape=lambda mesh, axes: tuple(mesh.shape[a] for a in axes),
    )


def _probe_pd_xt() -> StrategyProbe:
    import math

    def shape(dom, mesh, axes, ctx):
        A, B = (mesh.shape[a] for a in axes)
        gx = math.ceil(dom.Gx / A)
        gt = math.ceil(dom.Gt / B)
        return (gx + 2 * dom.Hs, dom.Gy, gt + 2 * dom.Ht)

    return StrategyProbe(
        prepare=lambda pts, dom, mesh, axes, cap:
            (_sd().prepare_pd_xt(pts, dom, mesh, axes, cap=cap), {}),
        build=lambda dom, mesh, axes, n, ctx:
            _sd().build_pd_xt(dom, mesh, axes, n),
        build_nocomm=lambda dom, mesh, axes, n, ctx:
            _sd().build_pd_xt(dom, mesh, axes, n, collectives=False),
        local_shape=shape,
        default_axes=_axes_workers,
        plan_shape=lambda mesh, axes: tuple(mesh.shape[a] for a in axes),
    )


def _probe_pd_xyt() -> StrategyProbe:
    import math

    def shape(dom, mesh, axes, ctx):
        A, B, C = (mesh.shape[a] for a in axes)
        return (
            math.ceil(dom.Gx / A) + 2 * dom.Hs,
            math.ceil(dom.Gy / B) + 2 * dom.Hs,
            math.ceil(dom.Gt / C) + 2 * dom.Ht,
        )

    return StrategyProbe(
        prepare=lambda pts, dom, mesh, axes, cap:
            (_sd().prepare_pd_xyt(pts, dom, mesh, axes, cap=cap), {}),
        build=lambda dom, mesh, axes, n, ctx:
            _sd().build_pd_xyt(dom, mesh, axes, n),
        build_nocomm=lambda dom, mesh, axes, n, ctx:
            _sd().build_pd_xyt(dom, mesh, axes, n, collectives=False),
        local_shape=shape,
        default_axes=_axes_xyz,
        plan_shape=lambda mesh, axes: tuple(mesh.shape[a] for a in axes),
    )


def _probe_dd_lpt() -> StrategyProbe:
    return StrategyProbe(
        prepare=lambda pts, dom, mesh, axes, cap:
            _sd().prepare_dd_lpt(pts, dom, mesh, axes, cap=cap),
        build=lambda dom, mesh, axes, n, ctx:
            _sd().build_dd_lpt(dom, mesh, axes, n, ctx["tile"], ctx["k"],
                               ctx["cap"], ctx["ntiles"]),
        build_nocomm=lambda dom, mesh, axes, n, ctx:
            _sd().build_dd_lpt(dom, mesh, axes, n, ctx["tile"], ctx["k"],
                               ctx["cap"], ctx["ntiles"],
                               collectives=False),
        local_shape=lambda dom, mesh, axes, ctx: tuple(
            nt * b for nt, b in zip(ctx["ntiles"], ctx["tile"])),
        default_axes=_axes_workers,
        plan_shape=lambda mesh, axes: tuple(mesh.shape[a] for a in axes),
    )


def _probe_hybrid() -> StrategyProbe:
    def shape(dom, mesh, axes, ctx):
        gx, gy = _worker_dims(dom, mesh, axes)
        return (gx + 2 * dom.Hs, gy + 2 * dom.Hs, dom.Gt)

    return StrategyProbe(
        prepare=lambda pts, dom, mesh, axes, cap:
            (_sd().prepare_hybrid(pts, dom, mesh, axes,
                                  rep_axis=_rep_axis(mesh, axes), cap=cap),
             {}),
        build=lambda dom, mesh, axes, n, ctx:
            _sd().build_pd(dom, mesh, axes, n,
                           rep_axis=_rep_axis(mesh, axes)),
        build_nocomm=lambda dom, mesh, axes, n, ctx:
            _sd().build_pd(dom, mesh, axes, n,
                           rep_axis=_rep_axis(mesh, axes),
                           collectives=False),
        local_shape=shape,
        default_axes=_axes_workers,
        plan_shape=lambda mesh, axes:
            (mesh.shape[_rep_axis(mesh, axes)],)
            + tuple(mesh.shape[a] for a in axes),
    )


# strategy name -> phase-probe spec; the full set the planner can be
# reconciled against. Iteration order is report order.
PROBED: Dict[str, StrategyProbe] = {
    "dr": _probe_dr(),
    "dd": _probe_dd(),
    "pd": _probe_pd(),
    "pd_xt": _probe_pd_xt(),
    "pd_xyt": _probe_pd_xyt(),
    "dd_lpt": _probe_dd_lpt(),
    "hybrid": _probe_hybrid(),
}


def measure_strategy(
    points: np.ndarray,
    dom,
    mesh,
    strategy: str,
    axes: Optional[Tuple[str, ...]] = None,
    reps: int = 3,
    cap: Optional[int] = None,
) -> Dict[str, float]:
    """Measured init/compute/comm/total seconds for one strategy.

    ``axes=None`` uses the strategy's ``default_axes`` on the given mesh
    (worker-2D strategies span the last two axes; dr spans all; pd_xyt
    needs exactly three).
    """
    import jax
    import jax.numpy as jnp

    spec = PROBED.get(strategy)
    if spec is None:
        raise ValueError(f"phase probes implemented for {tuple(PROBED)}, "
                         f"got {strategy!r}")
    pts = np.asarray(points, dtype=np.float32)
    n = len(pts)
    if axes is None:
        axes = spec.default_axes(mesh)

    with trace.span(f"reconcile.{strategy}.prepare", n=n):
        args, ctx = spec.prepare(pts, dom, mesh, axes, cap)
        local_shape = spec.local_shape(dom, mesh, axes, ctx)
        full = spec.build(dom, mesh, axes, n, ctx)
        nocomm = (full if spec.build_nocomm is None
                  else spec.build_nocomm(dom, mesh, axes, n, ctx))

    memset = jax.jit(lambda v: jnp.full(local_shape, v, jnp.float32))
    t_init = timing.timeit(
        lambda: memset(0.0), reps=reps,
        name=f"reconcile.{strategy}.init", strategy=strategy).best
    t_nocomm = timing.timeit(
        lambda: nocomm(*args), reps=reps,
        name=f"reconcile.{strategy}.nocomm", strategy=strategy).best
    if nocomm is full:
        t_full = t_nocomm
    else:
        t_full = timing.timeit(
            lambda: full(*args), reps=reps,
            name=f"reconcile.{strategy}.full", strategy=strategy).best
    return {
        "init_s": t_init,
        "compute_s": max(t_nocomm - t_init, 0.0),
        "comm_s": max(t_full - t_nocomm, 0.0),
        "total_s": t_full,
    }


def reconcile(
    predicted: Dict[str, Dict[str, float]],
    measured: Dict[str, Dict[str, float]],
) -> List[Dict]:
    """Join per-strategy predicted and measured cost tables term-by-term.

    Relative error convention: (measured - predicted) / max(predicted, eps)
    — positive means the planner was optimistic for that term.
    """
    rows = []
    for strat in measured:
        pred = predicted.get(strat, {})
        for term in TERMS:
            p = pred.get(term)
            m = measured[strat].get(term)
            if m is None:
                continue
            rel = None
            if p is not None:
                rel = (m - p) / max(abs(p), 1e-12)
            rows.append({
                "strategy": strat,
                "term": term,
                "predicted_s": p,
                "measured_s": m,
                "rel_err": rel,
            })
    return rows


def report_text(rows: List[Dict]) -> str:
    """Fixed-width reconciliation report (also rendered by make_report)."""
    lines = [
        f"{'strategy':<10} {'term':<10} {'predicted_s':>12} "
        f"{'measured_s':>12} {'rel_err':>9}",
        "-" * 57,
    ]
    for r in rows:
        p = "-" if r["predicted_s"] is None else f"{r['predicted_s']:.6f}"
        e = "-" if r["rel_err"] is None else f"{r['rel_err']:+.2f}"
        lines.append(
            f"{r['strategy']:<10} {r['term']:<10} {p:>12} "
            f"{r['measured_s']:>12.6f} {e:>9}"
        )
    return "\n".join(lines)


def run(
    points: np.ndarray,
    dom,
    mesh,
    strategies: Optional[Sequence[str]] = None,
    axes: Optional[Tuple[str, ...]] = None,
    reps: int = 3,
    hw=None,
) -> Dict:
    """Full reconciliation: plan, measure, join. Returns rows + report.

    ``strategies`` defaults to every registry key; ``axes=None`` lets each
    strategy pick its ``default_axes`` on the mesh (the recommended mode
    on a 3-axis mesh, where dr/pd_xyt/hybrid span different axis sets).
    Predictions are computed per strategy with its ``plan_shape`` so the
    planner prices the same decomposition the probe measures.
    """
    from repro.core import bucketing, plan

    pts = np.asarray(points, dtype=np.float32)
    if strategies is None:
        strategies = tuple(PROBED)
    hw = hw or _default_hw()

    # block imbalance measured on the worker home-bucket grid; shared by
    # every strategy's prediction (plan.estimate re-partitions per shape)
    wa, wb = _axes_workers(mesh)
    A, B = mesh.shape[wa], mesh.shape[wb]
    gx_loc, gy_loc = _sd()._device_grid_dims(dom, A, B)
    loads = bucketing.bucket_points_home(
        pts, dom, (gx_loc, gy_loc, dom.Gt)
    ).counts.reshape(-1).astype(np.float64)

    mesh_str = "x".join(str(int(mesh.shape[a])) for a in mesh.axis_names)
    predicted: Dict[str, Dict[str, float]] = {}
    measured: Dict[str, Dict[str, float]] = {}
    with trace.span("reconcile.measure", mesh=mesh_str):
        for strat in strategies:
            spec = PROBED.get(strat)
            if spec is None:
                raise ValueError(
                    f"phase probes implemented for {tuple(PROBED)}, "
                    f"got {strat!r}")
            s_axes = axes if axes is not None else spec.default_axes(mesh)
            table = plan.estimate(
                dom, len(pts), spec.plan_shape(mesh, s_axes),
                loads=loads, hw=hw)
            predicted[strat] = table[strat]
            measured[strat] = measure_strategy(
                pts, dom, mesh, strat, axes=s_axes, reps=reps
            )
    rows = reconcile(predicted, measured)
    if hw is plan.HOST:
        hw_name = "host"
    elif hw is plan.HOST_SEED:
        hw_name = "host_seed"
    else:
        hw_name = "v5e"
    return {
        "mesh": mesh_str,
        "n": int(len(pts)),
        "grid": f"{dom.Gx}x{dom.Gy}x{dom.Gt}",
        "hw": hw_name,
        "rows": rows,
        "report": report_text(rows),
    }
