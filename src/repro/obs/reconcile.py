"""Planner predicted-vs-measured reconciliation.

``core/plan.py`` prices every execution strategy with a three-term model
(init = HBM memset, compute = point work x imbalance, comm = collectives)
— this module closes the loop: it *measures* the same three terms on a live
mesh and joins them against the prediction, per strategy and per term, with
relative errors. Every future perf PR gets a phase-level baseline instead
of one opaque wall-clock number.

Measurement protocol (differential timing — host wall clocks cannot see
inside one jitted program):

  init_s     jitted memset of the strategy's per-device grid buffer
  nocomm     the strategy compiled with collectives stripped
             (``build_*(..., collectives=False)``; DD has none to strip)
  full       the production strategy

  measured.init    = t(init)
  measured.compute = max(t(nocomm) - t(init), 0)
  measured.comm    = max(t(full) - t(nocomm), 0)
  measured.total   = t(full)

All timings flow through ``obs.timing.timeit`` (shared warmup +
block_until_ready) and therefore appear as spans in the Chrome trace.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from . import timing, trace

TERMS = ("init_s", "compute_s", "comm_s", "total_s")

# strategies with a full phase-probe implementation
PROBED = ("dr", "dd", "pd")


def _default_hw():
    """V5E on TPU backends; calibrated host constants on CPU (so the
    smoke-run relative errors are about calibration, not CPU != TPU)."""
    from repro.core import plan

    return plan.default_hw()


def measure_strategy(
    points: np.ndarray,
    dom,
    mesh,
    strategy: str,
    axes: Tuple[str, str] = ("data", "model"),
    reps: int = 3,
    cap: Optional[int] = None,
) -> Dict[str, float]:
    """Measured init/compute/comm/total seconds for one strategy."""
    import jax
    import jax.numpy as jnp

    from repro.distributed import stkde_dist as sd

    if strategy not in PROBED:
        raise ValueError(f"phase probes implemented for {PROBED}, "
                         f"got {strategy!r}")
    pts = np.asarray(points, dtype=np.float32)
    n = len(pts)
    A, B = (mesh.shape[a] for a in axes)
    gx_loc, gy_loc = sd._device_grid_dims(dom, A, B)

    with trace.span(f"reconcile.{strategy}.prepare", n=n):
        if strategy == "dr":
            args = (sd.prepare_dr(pts, dom, mesh, axes),)
            local_shape = dom.grid_shape
            full = sd.build_dr(dom, mesh, axes, n)
            nocomm = sd.build_dr(dom, mesh, axes, n, collectives=False)
        elif strategy == "dd":
            args = sd.prepare_dd(pts, dom, mesh, axes, cap=cap)
            local_shape = (gx_loc, gy_loc, dom.Gt)
            full = sd.build_dd(dom, mesh, axes, n)
            nocomm = full                       # DD is communication-free
        else:  # pd
            args = sd.prepare_pd(pts, dom, mesh, axes, cap=cap)
            local_shape = (gx_loc + 2 * dom.Hs, gy_loc + 2 * dom.Hs, dom.Gt)
            full = sd.build_pd(dom, mesh, axes, n)
            nocomm = sd.build_pd(dom, mesh, axes, n, collectives=False)

    memset = jax.jit(lambda v: jnp.full(local_shape, v, jnp.float32))
    t_init = timing.timeit(
        lambda: memset(0.0), reps=reps,
        name=f"reconcile.{strategy}.init", strategy=strategy).best
    t_nocomm = timing.timeit(
        lambda: nocomm(*args), reps=reps,
        name=f"reconcile.{strategy}.nocomm", strategy=strategy).best
    if nocomm is full:
        t_full = t_nocomm
    else:
        t_full = timing.timeit(
            lambda: full(*args), reps=reps,
            name=f"reconcile.{strategy}.full", strategy=strategy).best
    return {
        "init_s": t_init,
        "compute_s": max(t_nocomm - t_init, 0.0),
        "comm_s": max(t_full - t_nocomm, 0.0),
        "total_s": t_full,
    }


def reconcile(
    predicted: Dict[str, Dict[str, float]],
    measured: Dict[str, Dict[str, float]],
) -> List[Dict]:
    """Join per-strategy predicted and measured cost tables term-by-term.

    Relative error convention: (measured - predicted) / max(predicted, eps)
    — positive means the planner was optimistic for that term.
    """
    rows = []
    for strat in measured:
        pred = predicted.get(strat, {})
        for term in TERMS:
            p = pred.get(term)
            m = measured[strat].get(term)
            if m is None:
                continue
            rel = None
            if p is not None:
                rel = (m - p) / max(abs(p), 1e-12)
            rows.append({
                "strategy": strat,
                "term": term,
                "predicted_s": p,
                "measured_s": m,
                "rel_err": rel,
            })
    return rows


def report_text(rows: List[Dict]) -> str:
    """Fixed-width reconciliation report (also rendered by make_report)."""
    lines = [
        f"{'strategy':<10} {'term':<10} {'predicted_s':>12} "
        f"{'measured_s':>12} {'rel_err':>9}",
        "-" * 57,
    ]
    for r in rows:
        p = "-" if r["predicted_s"] is None else f"{r['predicted_s']:.6f}"
        e = "-" if r["rel_err"] is None else f"{r['rel_err']:+.2f}"
        lines.append(
            f"{r['strategy']:<10} {r['term']:<10} {p:>12} "
            f"{r['measured_s']:>12.6f} {e:>9}"
        )
    return "\n".join(lines)


def run(
    points: np.ndarray,
    dom,
    mesh,
    strategies: Sequence[str] = PROBED,
    axes: Tuple[str, str] = ("data", "model"),
    reps: int = 3,
    hw=None,
) -> Dict:
    """Full reconciliation: plan, measure, join. Returns rows + report."""
    from repro.core import bucketing, plan

    pts = np.asarray(points, dtype=np.float32)
    A, B = (mesh.shape[a] for a in axes)
    hw = hw or _default_hw()
    from repro.distributed.stkde_dist import _device_grid_dims

    gx_loc, gy_loc = _device_grid_dims(dom, A, B)
    loads = bucketing.bucket_points_home(
        pts, dom, (gx_loc, gy_loc, dom.Gt)
    ).counts.reshape(-1).astype(np.float64)
    predicted = plan.estimate(dom, len(pts), (A, B), loads=loads, hw=hw)

    measured = {}
    with trace.span("reconcile.measure", mesh=f"{A}x{B}"):
        for strat in strategies:
            measured[strat] = measure_strategy(
                pts, dom, mesh, strat, axes=axes, reps=reps
            )
    rows = reconcile(predicted, measured)
    return {
        "mesh": f"{A}x{B}",
        "n": int(len(pts)),
        "grid": f"{dom.Gx}x{dom.Gy}x{dom.Gt}",
        "hw": "host" if hw is plan.HOST else "v5e",
        "rows": rows,
        "report": report_text(rows),
    }
