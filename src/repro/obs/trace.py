"""Nestable wall-clock tracing with Chrome-trace export.

The repo's timing story in one place: every phase worth watching (bucketing,
strategy execute, serve prefill/decode, train steps, benchmark reps) opens a
``span``. Spans nest per thread, carry free-form attributes, and export to
the Chrome trace-event JSON format (load in ``chrome://tracing`` or
Perfetto). Optionally each span also mirrors into
``jax.profiler.TraceAnnotation`` so host spans line up with device traces
when a JAX profile is being captured.

Naming convention (see docs/observability.md): dotted lowercase
``component.subject[.phase]`` — e.g. ``stkde.pd.execute``,
``serve.prefill``, ``train.step``, ``bench.table3.pb_sym``.

Dependency-free: stdlib only; jax is touched lazily and only when
mirroring is enabled.
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import os
import threading
import time
from typing import Any, Dict, Iterator, List, Optional

_NS_PER_US = 1_000


@dataclasses.dataclass
class Span:
    """One closed (or still-open) traced region."""

    name: str
    start_ns: int                     # relative to the tracer epoch
    duration_ns: Optional[int] = None
    tid: int = 0
    span_id: int = 0
    parent_id: Optional[int] = None
    attrs: Dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def duration_s(self) -> float:
        return 0.0 if self.duration_ns is None else self.duration_ns / 1e9

    def set(self, **attrs) -> "Span":
        """Attach attributes after the span opened (e.g. computed counts)."""
        self.attrs.update(attrs)
        return self

    def to_event(self, pid: int) -> Dict[str, Any]:
        """Chrome trace-event ("X" complete event, microsecond clock)."""
        return {
            "name": self.name,
            "ph": "X",
            "ts": self.start_ns / _NS_PER_US,
            "dur": (self.duration_ns or 0) / _NS_PER_US,
            "pid": pid,
            "tid": self.tid,
            "args": {k: _jsonable(v) for k, v in self.attrs.items()},
        }


def _jsonable(v):
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    return str(v)


class Tracer:
    """Thread-safe span recorder.

    One process-global instance (``get_tracer()``) backs the module-level
    ``span`` helper; independent instances can be created for tests.
    """

    def __init__(self, mirror_jax: bool = False):
        self._lock = threading.Lock()
        self._tls = threading.local()
        self._spans: List[Span] = []
        self._foreign: List[Dict[str, Any]] = []   # ingested child events
        self._next_id = 0
        self.enabled = True
        self.mirror_jax = mirror_jax
        self.epoch_ns = time.perf_counter_ns()

    # ------------------------------------------------------------- spans
    def _stack(self) -> List[Span]:
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = self._tls.stack = []
        return st

    @contextlib.contextmanager
    def span(self, name: str, **attrs) -> Iterator[Span]:
        if not self.enabled:
            yield Span(name=name, start_ns=0)
            return
        with self._lock:
            self._next_id += 1
            sid = self._next_id
        stack = self._stack()
        sp = Span(
            name=name,
            start_ns=time.perf_counter_ns() - self.epoch_ns,
            tid=threading.get_ident(),
            span_id=sid,
            parent_id=stack[-1].span_id if stack else None,
            attrs=dict(attrs),
        )
        stack.append(sp)
        mirror = self._jax_annotation(name) if self.mirror_jax else None
        if mirror is not None:
            mirror.__enter__()
        try:
            yield sp
        finally:
            if mirror is not None:
                mirror.__exit__(None, None, None)
            stack.pop()
            sp.duration_ns = (
                time.perf_counter_ns() - self.epoch_ns - sp.start_ns
            )
            with self._lock:
                self._spans.append(sp)

    @staticmethod
    def _jax_annotation(name: str):
        try:
            import jax

            return jax.profiler.TraceAnnotation(name)
        except Exception:
            return None

    # ----------------------------------------------------------- exports
    def spans(self, name: Optional[str] = None) -> List[Span]:
        """Closed spans, optionally filtered by exact name."""
        with self._lock:
            out = list(self._spans)
        if name is not None:
            out = [s for s in out if s.name == name]
        return out

    def to_chrome_trace(self) -> Dict[str, Any]:
        pid = os.getpid()
        with self._lock:
            events = [s.to_event(pid) for s in self._spans]
            events += [dict(e) for e in self._foreign]
        events.sort(key=lambda e: e["ts"])
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def save(self, path: str) -> None:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(), f, indent=1)

    def export_events(self) -> List[Dict[str, Any]]:
        """Chrome events for cross-process merge (see ``ingest``)."""
        return self.to_chrome_trace()["traceEvents"]

    def ingest(self, events: List[Dict[str, Any]],
               pid: Optional[int] = None) -> None:
        """Merge Chrome events produced by another process (e.g. the
        8-device benchmark subprocess) into this tracer's timeline."""
        with self._lock:
            for e in events:
                e = dict(e)
                if pid is not None:
                    e["pid"] = pid
                self._foreign.append(e)

    def clear(self) -> None:
        with self._lock:
            self._spans.clear()
            self._foreign.clear()
            self._next_id = 0
        self.epoch_ns = time.perf_counter_ns()


_TRACER = Tracer()


def get_tracer() -> Tracer:
    return _TRACER


def span(name: str, **attrs):
    """Open a span on the process-global tracer (context manager)."""
    return _TRACER.span(name, **attrs)


def set_mirror_jax(on: bool) -> None:
    """Mirror spans into ``jax.profiler.TraceAnnotation`` (device traces)."""
    _TRACER.mirror_jax = on


def save_chrome_trace(path: str) -> None:
    _TRACER.save(path)


def reset() -> None:
    _TRACER.clear()
