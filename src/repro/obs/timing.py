"""The one shared benchmark timer.

Every reported number in benchmarks/ and the runner flows through
``timeit`` so warmup, ``jax.block_until_ready`` and span/metric recording
happen in exactly one place (previously ~10 ad-hoc ``perf_counter``
snippets, each with its own blocking discipline).

``timeit`` is dependency-free: jax is imported lazily and only when the
result needs blocking; plain-python callables time fine without jax.
"""
from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Any, Callable, List, Optional

from . import metrics, trace


def _block(x: Any) -> Any:
    """jax.block_until_ready when jax is importable; identity otherwise."""
    try:
        import jax
    except Exception:
        return x
    try:
        return jax.block_until_ready(x)
    except Exception:
        return x


@dataclasses.dataclass
class TimingResult:
    name: str
    times: List[float]                 # per-rep seconds, in run order

    @property
    def best(self) -> float:
        return min(self.times)

    @property
    def mean(self) -> float:
        return statistics.fmean(self.times)

    @property
    def median(self) -> float:
        return statistics.median(self.times)


def timeit(
    fn: Callable[[], Any],
    reps: int = 3,
    warmup: int = 1,
    name: Optional[str] = None,
    block: bool = True,
    **attrs,
) -> TimingResult:
    """Time ``fn()`` over ``reps`` measured calls after ``warmup`` calls.

    Each measured rep is recorded as a span ``bench.<name>`` (attr
    ``rep=i``) and observed into histogram ``<name>_s`` when ``name`` is
    given. Returns all rep times; callers pick ``.best`` (min — the
    benchmark convention here) or ``.median``.
    """
    label = name or getattr(fn, "__name__", "anon")
    for _ in range(max(0, warmup)):
        out = fn()
        if block:
            _block(out)
    times = []
    hist = metrics.histogram(f"{label}_s") if name else None
    for i in range(max(1, reps)):
        with trace.span(f"bench.{label}", rep=i, **attrs):
            t0 = time.perf_counter()
            out = fn()
            if block:
                _block(out)
            dt = time.perf_counter() - t0
        times.append(dt)
        if hist is not None:
            hist.observe(dt)
    return TimingResult(name=label, times=times)
