"""Generic retry with exponential backoff + deterministic jitter.

``with_retry(fn, policy, site=...)`` is the one retry loop in the repo:
it classifies failures through ``errors.is_transient``, backs off
exponentially with seeded jitter (deterministic under a fixed seed — the
property the chaos tests assert), honors a wall-clock deadline, and emits
``resilience.retries`` / ``resilience.gave_up`` counters plus
``resilience.backoff_s`` / ``resilience.recovery_s`` histograms so the
benchmark report can price recovery overhead.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Iterator, Optional, Tuple, Type, TypeVar

from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace

from .errors import DeadlineExceededError, RetriesExhaustedError, is_transient
from .faults import _unit_roll

T = TypeVar("T")


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Exponential backoff: delay_i = min(base * mult**i, max) ± jitter."""

    max_attempts: int = 3
    base_delay_s: float = 0.01
    max_delay_s: float = 1.0
    multiplier: float = 2.0
    jitter: float = 0.5          # fraction of the delay randomized away
    deadline_s: Optional[float] = None   # wall-clock budget for all attempts
    retry_on: Tuple[Type[BaseException], ...] = ()  # extra retryable types
    seed: int = 0

    def delays(self, site: str = "") -> Iterator[float]:
        """The deterministic backoff schedule (attempt i -> sleep before
        attempt i+1). Jitter derives from (seed, site, attempt) only."""
        for i in range(self.max_attempts - 1):
            d = min(self.base_delay_s * self.multiplier**i,
                    self.max_delay_s)
            if self.jitter > 0:
                u = _unit_roll(self.seed, f"retry.{site}", i, "jitter")
                d *= 1.0 - self.jitter * u
            yield d


DEFAULT_POLICY = RetryPolicy()


def _retryable(exc: BaseException, policy: RetryPolicy) -> bool:
    return is_transient(exc) or isinstance(exc, policy.retry_on)


def with_retry(
    fn: Callable[[], T],
    policy: RetryPolicy = DEFAULT_POLICY,
    site: str = "",
    on_retry: Optional[Callable[[int, BaseException, float], None]] = None,
    sleep: Callable[[float], None] = time.sleep,
) -> T:
    """Call ``fn`` until it succeeds, retrying transient failures.

    Raises ``RetriesExhaustedError`` (cause = last failure) after
    ``max_attempts``, ``DeadlineExceededError`` when the next backoff
    would overrun ``policy.deadline_s``, and re-raises non-transient
    failures immediately. ``sleep`` is injectable for tests.
    """
    t0 = time.perf_counter()
    delays = policy.delays(site)
    last: Optional[BaseException] = None
    for attempt in range(1, policy.max_attempts + 1):
        try:
            out = fn()
            if attempt > 1:
                obs_metrics.histogram("resilience.recovery_s").observe(
                    time.perf_counter() - t0
                )
            return out
        except BaseException as e:  # noqa: BLE001 — classified below
            last = e
            if not _retryable(e, policy):
                raise
            delay = next(delays, None)
            if delay is None:  # attempts exhausted
                obs_metrics.counter("resilience.gave_up").inc()
                if site:
                    obs_metrics.counter(
                        f"resilience.gave_up.{site}").inc()
                raise RetriesExhaustedError(site, attempt, e)
            if policy.deadline_s is not None and (
                time.perf_counter() - t0 + delay > policy.deadline_s
            ):
                obs_metrics.counter("resilience.gave_up").inc()
                raise DeadlineExceededError(
                    f"{site or 'call'}: deadline {policy.deadline_s}s "
                    f"exhausted after {attempt} attempts"
                ) from e
            obs_metrics.counter("resilience.retries").inc()
            if site:
                obs_metrics.counter(f"resilience.retries.{site}").inc()
            obs_metrics.histogram("resilience.backoff_s").observe(delay)
            if on_retry is not None:
                on_retry(attempt, e, delay)
            with obs_trace.span("resilience.backoff", site=site,
                                attempt=attempt):
                sleep(delay)
    raise RetriesExhaustedError(site, policy.max_attempts,
                                last or RuntimeError("unreachable"))
