"""Graceful degradation for STKDE queries.

When a query cannot run at full fidelity (OOM, repeated strategy failure,
deadline pressure) we still owe the caller *an* answer: interactive
visualization tolerates a coarser or noisier density far better than a
500. Two degradation axes, applied per level:

  * **coarsen** — recompute on a grid with ``coarsen×`` larger voxels
    (memory and work drop ~coarsen³); error bounded by kernel variation
    across one voxel, ~``coarsen·sres/hs`` relative.
  * **subsample** — recompute on a coreset-style random fraction of the
    points (Zheng et al., 1709.04453); Monte-Carlo relative error
    ~``1/sqrt(n·frac)``.

Every degraded answer is tagged ``degraded=True`` with the level, reason,
and the combined error-bound estimate, and counted in
``resilience.degraded``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Optional, Sequence

import numpy as np

from repro.core.geometry import Domain
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace

from .errors import NonFiniteOutputError, ReproError, is_transient


@dataclasses.dataclass(frozen=True)
class DegradePolicy:
    """How to walk down fidelity levels on resource failure."""

    coarsen: float = 2.0        # voxel-size multiplier per level (1 = off)
    subsample: float = 0.5      # point fraction kept per level (1 = off)
    max_levels: int = 2
    seed: int = 0


@dataclasses.dataclass
class DegradedResult:
    """An STKDE answer that may have been computed below full fidelity."""

    grid: Any
    dom: Domain                 # the domain actually computed (may be coarse)
    degraded: bool = False
    level: int = 0
    reason: str = ""
    error_bound: float = 0.0    # relative-error estimate, 0 = exact


def coarsen_domain(dom: Domain, factor: float) -> Domain:
    """Same physical box, ``factor×`` larger voxels."""
    return dom.with_resolution(dom.sres * factor, dom.tres * factor)


def subsample_points(
    points: np.ndarray, frac: float, seed: int = 0
) -> np.ndarray:
    """Deterministic random coreset: keep ``ceil(n*frac)`` points."""
    pts = np.asarray(points)
    n = len(pts)
    keep = max(1, int(math.ceil(n * frac)))
    if keep >= n:
        return pts
    idx = np.random.default_rng(seed).choice(n, size=keep, replace=False)
    return pts[np.sort(idx)]


def error_bound(dom: Domain, n: int, level: int,
                policy: DegradePolicy) -> float:
    """Relative-error estimate for running ``level`` steps down.

    Coarsening contributes kernel variation across the larger voxel
    (~``Δres/hs``); subsampling contributes MC noise (~``1/sqrt(kept)``).
    Both are heuristics for UI display, not guarantees.
    """
    if level <= 0:
        return 0.0
    e_c = 0.0
    if policy.coarsen > 1.0:
        extra = dom.sres * (policy.coarsen**level - 1.0)
        e_c = extra / max(dom.hs, 1e-9)
    e_s = 0.0
    if policy.subsample < 1.0:
        kept = max(1.0, n * policy.subsample**level)
        e_s = 1.0 / math.sqrt(kept)
    return float(math.hypot(e_c, e_s))


def ensure_finite(grid, tag: str = "stkde"):
    """Raise NonFiniteOutputError when the density has NaN/Inf cells."""
    arr = np.asarray(grid)
    if not np.isfinite(arr).all():
        bad = int(np.size(arr) - np.isfinite(arr).sum())
        obs_metrics.counter("resilience.nonfinite").inc()
        raise NonFiniteOutputError(
            f"{tag}: {bad}/{arr.size} non-finite cells in output grid"
        )
    return grid


def run_with_degrade(
    compute: Callable[[np.ndarray, Domain], Any],
    points: np.ndarray,
    dom: Domain,
    policy: DegradePolicy = DegradePolicy(),
    tag: str = "stkde",
) -> DegradedResult:
    """Run ``compute(points, dom)``, walking down fidelity on failure.

    Level 0 is full fidelity; each subsequent level coarsens the grid and
    subsamples the points per ``policy``. Output is finite-validated at
    every level. Non-transient failures propagate immediately; running
    out of levels re-raises the last failure.
    """
    pts = np.asarray(points, dtype=np.float32)
    n = len(pts)
    last: Optional[BaseException] = None
    reasons: Sequence[str] = []
    for level in range(policy.max_levels + 1):
        d = dom if level == 0 else coarsen_domain(
            dom, policy.coarsen**level)
        p = pts if level == 0 or policy.subsample >= 1.0 else (
            subsample_points(pts, policy.subsample**level,
                             seed=policy.seed + level)
        )
        try:
            with obs_trace.span(f"resilience.degrade.{tag}", level=level,
                                n=len(p)):
                grid = ensure_finite(compute(p, d), tag)
            if level > 0:
                obs_metrics.counter("resilience.degraded").inc()
            return DegradedResult(
                grid=grid,
                dom=d,
                degraded=level > 0,
                level=level,
                reason=";".join(reasons),
                error_bound=error_bound(dom, n, level, policy),
            )
        except BaseException as e:  # noqa: BLE001 — classified below
            if not (is_transient(e) or isinstance(e, (ReproError,
                                                      ValueError))):
                raise
            last = e
            reasons = list(reasons) + [f"L{level}:{type(e).__name__}"]
    obs_metrics.counter("resilience.gave_up").inc()
    raise last if last is not None else RuntimeError("unreachable")
