"""Resilience layer: fault injection, retry/backoff, graceful degradation.

  errors   typed failure taxonomy + the ``is_transient`` retryability oracle
  faults   deterministic seedable fault injector (``REPRO_FAULTS`` env)
  retry    ``with_retry`` — exponential backoff + jitter + deadline
  degrade  coarsen/subsample fallback for STKDE queries (tagged results)
  journal  durable progress journal for crash-safe resumable STKDE

``faults``/``retry``/``errors`` depend only on stdlib + ``repro.obs``
(itself stdlib-only), so any layer of the stack can import them without
cycles; ``degrade``/``journal`` additionally use numpy (and ``degrade``
``core.geometry``).
"""
from . import degrade, errors, faults, journal, retry
from .degrade import DegradedResult, DegradePolicy, run_with_degrade
from .errors import (
    AdmissionError,
    CheckpointCorruptError,
    DeadlineExceededError,
    DeviceLostError,
    FaultInjectedError,
    JournalCorruptError,
    NonFiniteOutputError,
    ReproError,
    ReproValidationError,
    RetriesExhaustedError,
    is_transient,
)
from .journal import ProgressJournal, Salvage, fingerprint_of
from .faults import FaultInjector, configure, fault_point, get_injector
from .retry import RetryPolicy, with_retry

__all__ = [
    "degrade",
    "errors",
    "faults",
    "journal",
    "retry",
    "ProgressJournal",
    "Salvage",
    "fingerprint_of",
    "DeviceLostError",
    "JournalCorruptError",
    "DegradedResult",
    "DegradePolicy",
    "run_with_degrade",
    "AdmissionError",
    "CheckpointCorruptError",
    "DeadlineExceededError",
    "FaultInjectedError",
    "NonFiniteOutputError",
    "ReproError",
    "ReproValidationError",
    "RetriesExhaustedError",
    "is_transient",
    "FaultInjector",
    "configure",
    "fault_point",
    "get_injector",
    "RetryPolicy",
    "with_retry",
]
