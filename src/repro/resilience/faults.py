"""Deterministic, seedable fault injector (env/config-driven).

The injector is the chaos half of the resilience layer: production code
calls ``fault_point(site)`` / ``corrupt(site, data)`` / ``poison(site,
arr)`` at named sites, and a spec decides — deterministically — which of
those calls actually fail. With no spec every hook is a no-op costing one
dict lookup.

Spec grammar (env var ``REPRO_FAULTS`` or ``configure()``)::

    site:kind:rate[,site:kind:rate...]
    REPRO_FAULTS="serve.prefill:oom:0.1,ckpt.write:corrupt:0.25"
    REPRO_FAULTS="*:drop:0.05"          # wildcard: every known site

Sites:  serve.prefill  serve.decode  dist.halo  dist.device  ckpt.write
        journal.write  stkde.chunk   data.read
Kinds:  oom      raise InjectedOOMError (XlaRuntimeError-styled)
        drop     raise InjectedDropError
        delay    sleep ``param`` seconds (default 0.05)
        corrupt  bit-flip bytes passed through ``corrupt()``
        nan      NaN-poison arrays passed through ``poison()``

Determinism: each (site) keeps a call counter k; the decision for call k
derives from ``sha256(seed, site, k)`` — independent of wall clock,
thread timing, and of every other site. Same seed + same call sequence
⇒ identical faults, which is what makes chaos tests assertable.
"""
from __future__ import annotations

import dataclasses
import hashlib
import os
import struct
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

from repro.obs import metrics as obs_metrics

from .errors import InjectedDropError, InjectedOOMError, ReproValidationError

SITES = (
    "serve.prefill",
    "serve.decode",
    "dist.halo",
    "dist.device",
    "ckpt.write",
    "journal.write",
    "stkde.chunk",
    "data.read",
)
KINDS = ("oom", "drop", "delay", "corrupt", "nan")

ENV_SPEC = "REPRO_FAULTS"
ENV_SEED = "REPRO_FAULTS_SEED"


@dataclasses.dataclass(frozen=True)
class FaultRule:
    site: str
    kind: str
    rate: float
    param: float = 0.05  # delay seconds / corrupt flip density knob


def parse_spec(spec: str) -> List[FaultRule]:
    """Parse ``site:kind:rate[:param]`` comma list; '*' fans out to SITES."""
    rules: List[FaultRule] = []
    for part in (spec or "").split(","):
        part = part.strip()
        if not part:
            continue
        fields = part.split(":")
        if len(fields) not in (3, 4):
            raise ReproValidationError(
                f"bad fault rule {part!r}: want site:kind:rate[:param]"
            )
        site, kind, rate = fields[0], fields[1], float(fields[2])
        param = float(fields[3]) if len(fields) == 4 else 0.05
        if kind not in KINDS:
            raise ReproValidationError(
                f"unknown fault kind {kind!r} (have {KINDS})"
            )
        if not 0.0 <= rate <= 1.0:
            raise ReproValidationError(f"fault rate {rate} outside [0, 1]")
        sites = SITES if site in ("*", "all") else (site,)
        for s in sites:
            rules.append(FaultRule(site=s, kind=kind, rate=rate,
                                   param=param))
    return rules


def _unit_roll(seed: int, site: str, k: int, salt: str) -> float:
    """Deterministic uniform [0,1) from (seed, site, call-index, salt)."""
    h = hashlib.sha256(
        f"{seed}|{site}|{k}|{salt}".encode()
    ).digest()
    (x,) = struct.unpack("<Q", h[:8])
    return x / 2**64


class FaultInjector:
    """Deterministic per-site fault decisions; thread-safe counters."""

    def __init__(self, rules: Sequence[FaultRule] = (), seed: int = 0):
        self.seed = int(seed)
        self._rules: Dict[str, List[FaultRule]] = {}
        for r in rules:
            self._rules.setdefault(r.site, []).append(r)
        self._counts: Dict[str, int] = {}
        self._lock = threading.Lock()

    @classmethod
    def from_env(cls, env: Optional[Dict[str, str]] = None) -> "FaultInjector":
        env = os.environ if env is None else env
        return cls(parse_spec(env.get(ENV_SPEC, "")),
                   seed=int(env.get(ENV_SEED, "0")))

    @property
    def active(self) -> bool:
        return bool(self._rules)

    def _next_k(self, site: str) -> int:
        with self._lock:
            k = self._counts.get(site, 0)
            self._counts[site] = k + 1
            return k

    def _trigger(self, site: str, kinds: Tuple[str, ...]
                 ) -> Optional[FaultRule]:
        rules = [r for r in self._rules.get(site, ()) if r.kind in kinds]
        if not rules:
            return None
        k = self._next_k(site)
        for i, r in enumerate(rules):
            if _unit_roll(self.seed, site, k, f"{r.kind}{i}") < r.rate:
                obs_metrics.counter("resilience.injected").inc()
                obs_metrics.counter(f"resilience.injected.{site}").inc()
                return r
        return None

    # ------------------------------------------------------------ hooks
    def maybe_fail(self, site: str) -> None:
        """Control-flow faults: raise (oom/drop) or stall (delay)."""
        r = self._trigger(site, ("oom", "drop", "delay"))
        if r is None:
            return
        if r.kind == "oom":
            raise InjectedOOMError(site)
        if r.kind == "drop":
            raise InjectedDropError(site)
        time.sleep(r.param)

    def corrupt(self, site: str, data: bytes) -> bytes:
        """Data faults: flip a few bytes of ``data`` when triggered."""
        r = self._trigger(site, ("corrupt",))
        if r is None or not data:
            return data
        out = bytearray(data)
        n_flips = max(1, int(len(out) * min(r.param, 0.01)))
        for i in range(n_flips):
            pos = int(_unit_roll(self.seed, site, i, "pos") * len(out))
            out[pos] ^= 0xFF
        return bytes(out)

    def poison(self, site: str, arr):
        """Output faults: NaN-poison an array when triggered."""
        r = self._trigger(site, ("nan",))
        if r is None:
            return arr
        import numpy as np

        if hasattr(arr, "at"):  # jax array
            return arr * np.float32(np.nan)
        out = np.array(arr, copy=True)
        out.reshape(-1)[:: max(1, out.size // 8)] = np.nan
        return out

    def reset_counts(self) -> None:
        with self._lock:
            self._counts.clear()


# ------------------------------------------------------- global injector
_INJECTOR: Optional[FaultInjector] = None
_GLOBAL_LOCK = threading.Lock()


def get_injector() -> FaultInjector:
    global _INJECTOR
    with _GLOBAL_LOCK:
        if _INJECTOR is None:
            _INJECTOR = FaultInjector.from_env()
        return _INJECTOR


def configure(spec: str, seed: int = 0) -> FaultInjector:
    """Install a process-global injector from a spec string."""
    global _INJECTOR
    inj = FaultInjector(parse_spec(spec), seed=seed)
    with _GLOBAL_LOCK:
        _INJECTOR = inj
    return inj


def reset() -> None:
    """Drop the global injector; next use re-derives from the env."""
    global _INJECTOR
    with _GLOBAL_LOCK:
        _INJECTOR = None


# Module-level conveniences used at the named sites in production code.
def fault_point(site: str) -> None:
    get_injector().maybe_fail(site)


def corrupt(site: str, data: bytes) -> bytes:
    return get_injector().corrupt(site, data)


def poison(site: str, arr):
    return get_injector().poison(site, arr)
