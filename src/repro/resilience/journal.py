"""Durable progress journal for crash-safe resumable STKDE.

A chunked STKDE run (``core.api.stkde_chunked``) accumulates per-chunk
grid contributions into a float64 accumulator. After every chunk it
lands (a) a verified ``.npy`` snapshot of the accumulator and (b) an
append-only journal record naming the chunk, its point range, the plan
fingerprint, and the snapshot's CRC-32. A run killed at any instant —
including mid-write — can be resumed: ``replay()`` walks the journal,
drops the truncated/corrupt tail, and salvages the newest chunk whose
snapshot still verifies. Because the accumulator is restored bit-exactly
(``.npy`` round-trips float64 exactly) and chunks are deterministic,
an interrupted-then-resumed run produces a grid *bit-identical* to an
uninterrupted one.

On-disk layout (one directory per run)::

    <journal>/journal.bin          append-only records
    <journal>/grid_00000012.npy    float64 accumulator after chunk 12
                                   (keep-last-K, like train/checkpoint.py)

Record wire format (little-endian)::

    b"STKJ" | payload_len:u32 | crc32(payload):u32 | payload(JSON)

Record kinds: ``meta`` (first record: fingerprint + run parameters),
``chunk`` (one per landed chunk), ``event`` (recovery annotations, e.g.
mesh shrink). Writes reuse the checkpoint layer's write-verify pattern:
payload bytes pass the ``journal.write`` fault site, are fsynced,
re-read, and CRC-checked; a mismatch truncates the partial append and
retries (``JournalCorruptError`` is transient at write time).
"""
from __future__ import annotations

import dataclasses
import hashlib
import io
import json
import os
import struct
import zlib
from typing import Any, Dict, Iterator, List, Optional, Tuple

import numpy as np

from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace

from . import faults as _faults
from .errors import JournalCorruptError, ReproValidationError
from .retry import RetryPolicy, with_retry

MAGIC = b"STKJ"
_HEADER = struct.Struct("<4sII")  # magic, payload_len, payload_crc32

# same shape as checkpoint's write policy: corruption/IO hiccups re-write
# quickly, persistent corruption is a real error
_WRITE_POLICY = RetryPolicy(max_attempts=5, base_delay_s=0.01,
                            max_delay_s=0.2)

_SNAPSHOT_FMT = "grid_{:08d}.npy"


def fingerprint_of(**fields: Any) -> str:
    """Stable plan fingerprint: sha256 of canonical-JSON key/value pairs.

    Callers pass everything that must match between the original run and
    a resume for the replayed chunks to be valid: domain fields, global
    point count, chunk size, requested strategy, kernel names. The mesh
    is deliberately *not* part of it — mesh shrink mid-run must not
    invalidate the journal.
    """
    blob = json.dumps(fields, sort_keys=True, default=str)
    return hashlib.sha256(blob.encode()).hexdigest()


@dataclasses.dataclass
class Salvage:
    """What ``replay()`` recovered from a journal."""

    meta: Optional[Dict[str, Any]]      # meta payload, None if unusable
    chunk_id: int                       # newest salvaged chunk (-1: none)
    grid: Optional[np.ndarray]          # float64 accumulator after chunk_id
    ranges: Dict[int, Tuple[int, int]]  # chunk_id -> (start, stop)
    events: List[Dict[str, Any]]        # recovery events in the valid prefix
    dropped_tail: int = 0               # corrupt/truncated records dropped
    dropped_snapshots: int = 0          # chunk records without a live snapshot


def _crc(data: bytes) -> int:
    return zlib.crc32(data) & 0xFFFFFFFF


def _encode(payload: Dict[str, Any]) -> Tuple[bytes, bytes]:
    body = json.dumps(payload, sort_keys=True).encode()
    return _HEADER.pack(MAGIC, len(body), _crc(body)), body


class ProgressJournal:
    """Append-only, CRC-verified progress journal (one directory per run)."""

    def __init__(self, path: str, keep: int = 2):
        if keep < 1:
            raise ReproValidationError(f"journal keep must be >= 1: {keep}")
        self.dir = str(path)
        self.keep = int(keep)

    # ------------------------------------------------------------ paths
    @property
    def journal_file(self) -> str:
        return os.path.join(self.dir, "journal.bin")

    def snapshot_file(self, chunk_id: int) -> str:
        return os.path.join(self.dir, _SNAPSHOT_FMT.format(chunk_id))

    def exists(self) -> bool:
        return os.path.exists(self.journal_file)

    # ----------------------------------------------------------- create
    def create(self, fingerprint: str, meta: Optional[Dict[str, Any]] = None
               ) -> None:
        """Start a fresh journal (truncates any previous run's state)."""
        os.makedirs(self.dir, exist_ok=True)
        for f in os.listdir(self.dir):
            if f.startswith("grid_") or f.endswith(".tmp"):
                os.remove(os.path.join(self.dir, f))
        with open(self.journal_file, "wb"):
            pass
        self._append_record(
            {"kind": "meta", "fingerprint": fingerprint,
             "meta": dict(meta or {})}
        )

    def meta(self) -> Optional[Dict[str, Any]]:
        """The meta payload of an existing journal (None if unreadable)."""
        if not self.exists():
            return None
        recs, _, _ = self._read_records()
        if recs and recs[0][0].get("kind") == "meta":
            return recs[0][0]
        return None

    # ----------------------------------------------------------- append
    def append_chunk(self, chunk_id: int, start: int, stop: int,
                     grid: np.ndarray, **extra: Any) -> None:
        """Land one completed chunk: verified snapshot, then its record.

        Ordering is the crash-safety invariant: the snapshot is fully
        landed (written, re-read, CRC-verified, atomically renamed)
        *before* the record that names it is appended. A crash between
        the two leaves an orphan snapshot (harmless); a record can never
        name a snapshot that was not durably written.
        """
        acc = np.ascontiguousarray(grid, dtype=np.float64)
        crc = self._write_snapshot(chunk_id, acc)
        self._append_record({
            "kind": "chunk", "chunk_id": int(chunk_id),
            "start": int(start), "stop": int(stop),
            "grid_crc32": crc, "snapshot": _SNAPSHOT_FMT.format(chunk_id),
            **extra,
        })
        self._prune_snapshots(chunk_id)
        obs_metrics.counter("journal.chunks").inc()

    def append_event(self, event: Dict[str, Any]) -> None:
        """Append a recovery annotation (mesh shrink, strategy change)."""
        self._append_record({"kind": "event", **event})
        obs_metrics.counter("journal.events").inc()

    # ----------------------------------------------------------- replay
    def replay(self, expect_fingerprint: Optional[str] = None,
               truncate: bool = False) -> Salvage:
        """Parse the valid record prefix and salvage the newest restorable
        accumulator state.

        Corrupt or truncated tail records are *dropped*, never fatal; a
        fingerprint mismatch against ``expect_fingerprint`` raises a
        typed ``ReproValidationError`` (resuming a journal written by a
        different plan would silently produce a wrong grid). With
        ``truncate=True`` the journal file is cut back to the salvage
        point so subsequent appends continue from a consistent state.
        """
        with obs_trace.span("journal.replay", path=self.dir):
            recs, dropped_tail, _ = self._read_records()
            if dropped_tail:
                obs_metrics.counter("journal.dropped_tail").inc(dropped_tail)
            if not recs or recs[0][0].get("kind") != "meta":
                # nothing trustworthy (missing/corrupt meta): salvage nothing
                return Salvage(meta=None, chunk_id=-1, grid=None, ranges={},
                               events=[], dropped_tail=dropped_tail)
            meta = recs[0][0]
            if (expect_fingerprint is not None
                    and meta.get("fingerprint") != expect_fingerprint):
                raise ReproValidationError(
                    "journal fingerprint mismatch: journal was written by a "
                    "different plan (domain / n_total / chunk_size / "
                    f"strategy / kernels) — {self.journal_file} has "
                    f"{meta.get('fingerprint')!r}, caller expects "
                    f"{expect_fingerprint!r}. Refusing to resume."
                )
            chunks: List[Tuple[Dict[str, Any], int]] = []
            events: List[Dict[str, Any]] = []
            ranges: Dict[int, Tuple[int, int]] = {}
            next_id = 0
            end_meta = recs[0][1]
            for payload, end in recs[1:]:
                kind = payload.get("kind")
                if kind == "event":
                    events.append(payload)
                elif kind == "chunk":
                    if payload.get("chunk_id") != next_id:
                        break  # out-of-order/gapped tail: distrust the rest
                    chunks.append((payload, end))
                    ranges[next_id] = (payload["start"], payload["stop"])
                    next_id += 1

            dropped_snaps = 0
            for payload, end in reversed(chunks):
                grid = self._load_snapshot(payload)
                if grid is not None:
                    cid = payload["chunk_id"]
                    if truncate:
                        self._truncate(end)
                    obs_metrics.counter("journal.salvaged_chunks").inc(
                        cid + 1)
                    return Salvage(
                        meta=meta, chunk_id=cid, grid=grid,
                        ranges={i: ranges[i] for i in range(cid + 1)},
                        events=events, dropped_tail=dropped_tail,
                        dropped_snapshots=dropped_snaps)
                dropped_snaps += 1
            if truncate:
                self._truncate(end_meta)
            return Salvage(meta=meta, chunk_id=-1, grid=None, ranges={},
                           events=events, dropped_tail=dropped_tail,
                           dropped_snapshots=dropped_snaps)

    # --------------------------------------------------------- internals
    def _append_record(self, payload: Dict[str, Any]) -> None:
        header, body = _encode(payload)

        def write_once():
            _faults.fault_point("journal.write")
            # corruption models an in-flight bit flip: the header CRC is
            # computed from the clean payload, so a flipped byte fails
            # the read-back check below and the append is retried
            data = header + _faults.corrupt("journal.write", body)
            with open(self.journal_file, "ab") as f:
                off = f.tell()
                f.write(data)
                f.flush()
                os.fsync(f.fileno())
            with open(self.journal_file, "rb") as f:
                f.seek(off)
                got = f.read(len(data))
            if got != header + body:
                self._truncate(off)
                raise JournalCorruptError(
                    f"journal record failed write verification at "
                    f"offset {off} ({self.journal_file})"
                )

        with obs_trace.span("journal.write", kind=payload.get("kind", "?")):
            with_retry(write_once, policy=_WRITE_POLICY,
                       site="journal.write")
        obs_metrics.counter("journal.writes").inc()

    def _write_snapshot(self, chunk_id: int, acc: np.ndarray) -> int:
        """Write-verify the float64 accumulator snapshot; returns its CRC."""
        final = self.snapshot_file(chunk_id)
        tmp = final + ".tmp"
        crc = _crc(acc.tobytes())
        buf = io.BytesIO()
        np.save(buf, acc)
        body = buf.getvalue()

        def write_once():
            _faults.fault_point("journal.write")
            data = _faults.corrupt("journal.write", body)
            with open(tmp, "wb") as f:
                f.write(data)
                f.flush()
                os.fsync(f.fileno())
            try:
                back = np.load(tmp)
                ok = (back.dtype == acc.dtype and back.shape == acc.shape
                      and _crc(back.tobytes()) == crc)
            except Exception:
                ok = False
            if not ok:
                raise JournalCorruptError(
                    f"snapshot failed write verification: {tmp}"
                )
            os.replace(tmp, final)

        with obs_trace.span("journal.snapshot", chunk=chunk_id,
                            bytes=len(body)):
            with_retry(write_once, policy=_WRITE_POLICY,
                       site="journal.write")
        return crc

    def _load_snapshot(self, payload: Dict[str, Any]) -> Optional[np.ndarray]:
        path = os.path.join(self.dir, payload.get("snapshot", ""))
        if not os.path.exists(path):
            return None
        try:
            grid = np.load(path)
        except Exception:
            return None
        if (grid.dtype != np.float64
                or _crc(grid.tobytes()) != payload.get("grid_crc32")):
            return None
        return grid

    def _prune_snapshots(self, newest_id: int) -> None:
        """Keep-last-K snapshots (train/checkpoint.py pattern): older
        accumulator states are recoverable by recomputation anyway."""
        cutoff = newest_id - self.keep + 1
        for f in os.listdir(self.dir):
            if not (f.startswith("grid_") and f.endswith(".npy")):
                continue
            try:
                cid = int(f[5:-4])
            except ValueError:
                continue
            if cid < cutoff:
                os.remove(os.path.join(self.dir, f))

    def _truncate(self, offset: int) -> None:
        with open(self.journal_file, "r+b") as f:
            f.truncate(offset)

    def _read_records(self) -> Tuple[List[Tuple[Dict[str, Any], int]],
                                     int, int]:
        """All structurally valid records from the head of the file.

        Returns ``(records, dropped_tail, valid_end)`` where records are
        ``(payload, end_offset)`` pairs. Parsing stops at the first bad
        magic / short read / CRC mismatch / JSON failure — everything
        after that point is the crash-truncated tail.
        """
        out: List[Tuple[Dict[str, Any], int]] = []
        if not self.exists():
            return out, 0, 0
        with open(self.journal_file, "rb") as f:
            data = f.read()
        off = 0
        while off < len(data):
            head = data[off:off + _HEADER.size]
            if len(head) < _HEADER.size:
                break
            magic, ln, crc = _HEADER.unpack(head)
            if magic != MAGIC:
                break
            body = data[off + _HEADER.size: off + _HEADER.size + ln]
            if len(body) < ln or _crc(body) != crc:
                break
            try:
                payload = json.loads(body.decode())
            except (ValueError, UnicodeDecodeError):
                break
            off += _HEADER.size + ln
            out.append((payload, off))
        dropped = 1 if off < len(data) else 0
        return out, dropped, off


def iter_records(path: str) -> Iterator[Dict[str, Any]]:
    """Debugging helper: iterate the valid record payloads of a journal."""
    recs, _, _ = ProgressJournal(path)._read_records()
    for payload, _ in recs:
        yield payload
