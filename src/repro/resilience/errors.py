"""Typed error taxonomy for the resilience layer.

Every failure the stack can recover from (or deliberately surface) has a
class here, so callers branch on type instead of string-matching messages.
``is_transient`` is the single retryability oracle used by ``retry`` —
injected faults, real XLA RESOURCE_EXHAUSTED errors, and filesystem
hiccups are transient; validation errors never are.
"""
from __future__ import annotations


class ReproError(Exception):
    """Base class for every typed error raised by this repo."""


class ReproValidationError(ReproError, ValueError):
    """Malformed input rejected at the API boundary (never retried)."""


class AdmissionError(ReproError):
    """Request rejected at submit time (queue full, over limits).

    ``reason`` is a stable machine-readable slug (e.g. ``queue_full``).
    """

    def __init__(self, reason: str, detail: str = ""):
        self.reason = reason
        super().__init__(detail or reason)


class DeadlineExceededError(ReproError):
    """A per-request or per-call deadline elapsed."""


class NonFiniteOutputError(ReproError):
    """A kernel/strategy produced NaN/Inf where finite density was due."""


class CheckpointCorruptError(ReproError):
    """Checkpoint bytes failed checksum / structural verification."""


class JournalCorruptError(CheckpointCorruptError):
    """A progress-journal record or grid snapshot failed verification.

    Transient at *write* time (the journal re-writes through ``with_retry``
    like checkpoints do); at *replay* time it is handled structurally —
    corrupt tail records are dropped, never retried.
    """


class DeviceLostError(ReproError):
    """A device in the active mesh died mid-computation (never retried
    on the same mesh — the chunked executor re-plans onto a shrunken
    mesh instead; see docs/resilience.md "Resumable execution")."""

    def __init__(self, site: str, mesh_shape=None):
        self.site = site
        self.mesh_shape = tuple(mesh_shape) if mesh_shape else None
        super().__init__(
            f"DEVICE_LOST: [{site}] device failed mid-run"
            + (f" on mesh {self.mesh_shape}" if self.mesh_shape else "")
        )


class RetriesExhaustedError(ReproError):
    """``with_retry`` gave up; ``__cause__`` holds the last failure."""

    def __init__(self, site: str, attempts: int, last: BaseException):
        self.site = site
        self.attempts = attempts
        super().__init__(
            f"{site or 'call'}: gave up after {attempts} attempts: "
            f"{type(last).__name__}: {last}"
        )
        self.__cause__ = last


# ------------------------------------------------------------ injected
class FaultInjectedError(ReproError):
    """Base for faults raised by the deterministic injector."""

    def __init__(self, site: str, msg: str):
        self.site = site
        super().__init__(msg)


class InjectedOOMError(FaultInjectedError):
    """Styled after jaxlib's XlaRuntimeError RESOURCE_EXHAUSTED."""

    def __init__(self, site: str):
        super().__init__(
            site,
            f"RESOURCE_EXHAUSTED: [injected@{site}] Out of memory while "
            "trying to allocate 9437184000 bytes.",
        )


class InjectedDropError(FaultInjectedError):
    """A work item was dropped / a read failed (transient)."""

    def __init__(self, site: str):
        super().__init__(site, f"UNAVAILABLE: [injected@{site}] work item "
                               "dropped")


_TRANSIENT = (
    InjectedOOMError,
    InjectedDropError,
    NonFiniteOutputError,
    CheckpointCorruptError,
    OSError,
)


def is_transient(exc: BaseException) -> bool:
    """Retryability oracle: injected faults, OOMs, I/O errors — not
    validation/admission errors, not arbitrary bugs."""
    if isinstance(exc, (ReproValidationError, AdmissionError,
                        DeadlineExceededError, DeviceLostError)):
        return False
    if isinstance(exc, _TRANSIENT):
        return True
    # real XLA OOMs surface as jaxlib.XlaRuntimeError RESOURCE_EXHAUSTED;
    # match structurally so we need no jaxlib import here
    if type(exc).__name__ == "XlaRuntimeError":
        s = str(exc)
        return "RESOURCE_EXHAUSTED" in s or "UNAVAILABLE" in s
    return False
