"""repro — Parallel Space-Time Kernel Density Estimation on TPU pods.

A production-grade JAX framework reproducing Saule et al. (2017) and
re-architecting its algorithms (PB-SYM + DR/DD/PD/SCHED/REP parallel
strategies) for multi-pod TPU meshes, embedded in a full training/serving
substrate (see DESIGN.md).
"""
__version__ = "1.0.0"

from . import compat  # noqa: E402,F401  (installs JAX version shims)
