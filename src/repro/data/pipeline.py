"""Deterministic synthetic data pipeline (per-host sharded).

Produces token streams with learnable n-gram structure (so tiny models can
visibly reduce loss in the e2e example) from a counter-based hash — fully
deterministic, seekable by step (restart-safe: resuming at step N yields
exactly the batches a non-crashed run would have seen), and shardable by
host: host h of H draws rows [h::H] of the global batch.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, Iterator, Optional, Tuple

import numpy as np

from repro.resilience import RetryPolicy, faults, with_retry
from repro.resilience.errors import ReproValidationError

# transient read faults (dropped shards, storage hiccups) retry quickly;
# a batch that cannot be produced after that is a real error
_READ_POLICY = RetryPolicy(max_attempts=4, base_delay_s=0.005,
                           max_delay_s=0.1)


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_host: int = 1
    host_id: int = 0
    # markov-chain structure strength (0 = uniform noise, 1 = deterministic)
    structure: float = 0.8


class SyntheticLM:
    """Order-1 Markov token stream with a fixed random transition table."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v = min(cfg.vocab, 4096)  # structured sub-vocab
        self.v = v
        self.next_tok = rng.integers(0, v, size=(v, 4))

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Batch for ``step`` (retried through the ``data.read`` fault
        site — the stream is seekable, so a re-read is exact)."""
        return with_retry(lambda: self._batch_at(step),
                          policy=_READ_POLICY, site="data.read")

    def _batch_at(self, step: int) -> Dict[str, np.ndarray]:
        faults.fault_point("data.read")
        cfg = self.cfg
        rows = np.arange(cfg.host_id, cfg.global_batch, cfg.n_host)
        B = len(rows)
        # counter-based determinism: seed from (step, row)
        seqs = np.empty((B, cfg.seq_len + 1), np.int32)
        for i, r in enumerate(rows):
            rng = np.random.default_rng(
                np.random.SeedSequence([cfg.seed, step, int(r)])
            )
            toks = np.empty(cfg.seq_len + 1, np.int32)
            toks[0] = rng.integers(0, self.v)
            noise = rng.random(cfg.seq_len)
            branch = rng.integers(0, 4, cfg.seq_len)
            rand = rng.integers(0, self.v, cfg.seq_len)
            for t in range(cfg.seq_len):
                if noise[t] < cfg.structure:
                    toks[t + 1] = self.next_tok[toks[t], branch[t]]
                else:
                    toks[t + 1] = rand[t]
            seqs[i] = toks
        return {
            "tokens": seqs[:, :-1],
            "labels": seqs[:, 1:],
        }

    def iter_from(self, step: int) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.batch_at(step)
            step += 1


def stkde_stream(instance, chunk: int = 100_000, seed: Optional[int] = None):
    """Chunked point stream for out-of-core STKDE (eBird-scale ingestion).

    Yields (chunk_i, n_total) so accumulation strategies can stream points
    through the grid without materializing all n at once.
    """
    n = instance.n
    done = 0
    i = 0
    while done < n:
        take = min(chunk, n - done)
        sub = dataclasses.replace(
            instance, n=take,
            seed=(instance.seed if seed is None else seed) + 7919 * i,
        )

        def read_chunk(sub=sub):
            faults.fault_point("data.read")
            return sub.points()

        yield with_retry(read_chunk, policy=_READ_POLICY,
                         site="data.read"), n
        done += take
        i += 1


def as_chunks(points, chunk_size: Optional[int] = None,
              n_total: Optional[int] = None
              ) -> Tuple[Iterator[Tuple[int, int, int, np.ndarray]], int]:
    """Normalize a point source into a bounded-memory chunk iterator.

    Accepts either an in-memory ``(n, 3)`` array (sliced into
    ``chunk_size`` pieces without copying the whole set again) or an
    iterable of chunks — plain arrays, or the ``(chunk, n_total)`` pairs
    ``stkde_stream`` yields. Returns ``(iterator, n_total)`` where the
    iterator yields ``(chunk_id, start, stop, pts)``; peak point-buffer
    memory is one chunk. The global count must be known up front (STKDE
    normalization divides by it): it is taken from the array length, the
    stream protocol, or the explicit ``n_total`` argument.
    """
    if isinstance(points, np.ndarray) or isinstance(points, (list, tuple)):
        pts = np.asarray(points, dtype=np.float32)
        if pts.ndim != 2 or pts.shape[1] != 3:
            raise ReproValidationError(
                f"points must be (n, 3) [x, y, t]; got shape {pts.shape}"
            )
        n = len(pts)
        if not chunk_size or chunk_size <= 0:
            raise ReproValidationError(
                f"chunk_size must be a positive int: {chunk_size!r}"
            )

        def from_array():
            for i, s in enumerate(range(0, n, chunk_size)):
                stop = min(s + chunk_size, n)
                yield i, s, stop, pts[s:stop]

        return from_array(), n

    it = iter(points)
    try:
        first = next(it)
    except StopIteration:
        raise ReproValidationError("empty point source") from None
    if isinstance(first, tuple):  # stkde_stream protocol: (chunk, n_total)
        n_total = int(first[1])
    if n_total is None:
        raise ReproValidationError(
            "streaming point sources need n_total (pass stkde_stream, or "
            "give n_total= explicitly) — STKDE normalization divides by "
            "the global point count before the stream is exhausted"
        )

    def from_stream(n=int(n_total)):
        start = 0
        for i, item in enumerate(itertools.chain([first], it)):
            chunk = np.asarray(item[0] if isinstance(item, tuple) else item,
                               dtype=np.float32)
            stop = start + len(chunk)
            if stop > n:
                raise ReproValidationError(
                    f"point stream produced {stop} > n_total={n} points"
                )
            yield i, start, stop, chunk
            start = stop

    return from_stream(), int(n_total)
