"""Data pipelines: deterministic synthetic LM stream + STKDE point streams."""
from .pipeline import DataConfig, SyntheticLM, stkde_stream
