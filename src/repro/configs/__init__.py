"""Config registry: the 10 assigned LM architectures + STKDE instances."""
from .lm_archs import ARCHS, get_arch, reduced
from repro.core.datasets import INSTANCES as STKDE_INSTANCES

__all__ = ["ARCHS", "get_arch", "reduced", "STKDE_INSTANCES"]
