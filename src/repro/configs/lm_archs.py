"""The 10 assigned architectures — exact configs from the assignment table.

Each entry also defines ``reduced()``: a same-family CPU smoke variant
(small width/depth/experts) used by tests/test_arch_smoke.py. Full configs
are exercised only via the AOT dry-run (launch/dryrun.py).
"""
from __future__ import annotations

from repro.models.config import ModelConfig

_COMMON = dict(compute_dtype="bfloat16", param_dtype="float32", remat=True)


ARCHS = {}


def _register(cfg: ModelConfig):
    ARCHS[cfg.name] = cfg
    return cfg


# --- rwkv6-3b [ssm] 32L d_model=2560 (attn-free) d_ff=8960 vocab=65536
#     Finch — data-dependent decay [arXiv:2404.05892]
_register(ModelConfig(
    name="rwkv6-3b", family="ssm", train_parallelism="fsdp", n_layers=32, d_model=2560,
    n_heads=40, n_kv_heads=40, d_ff=8960, vocab=65536,
    mixer="rwkv6", mlp="rwkv6_cmix", use_rope=False, **_COMMON,
))

# --- mistral-nemo-12b [dense] 40L d=5120 32H (GQA kv=8) ff=14336 v=131072
#     128k ctx [hf:mistralai/Mistral-Nemo-Base-2407]
_register(ModelConfig(
    name="mistral-nemo-12b", family="dense", n_layers=40, d_model=5120,
    n_heads=32, n_kv_heads=8, d_head=128, d_ff=14336, vocab=131072,
    rope_theta=1e6, max_seq=131072, **_COMMON,
))

# --- smollm-360m [dense] 32L d=960 15H (GQA kv=5) ff=2560 v=49152
#     llama-arch small [hf:HuggingFaceTB/SmolLM-360M]
_register(ModelConfig(
    name="smollm-360m", family="dense", train_parallelism="fsdp", n_layers=32, d_model=960,
    n_heads=15, n_kv_heads=5, d_ff=2560, vocab=49152,
    tie_embeddings=True, **_COMMON,
))

# --- stablelm-12b [dense] 40L d=5120 32H (GQA kv=8) ff=13824 v=100352
#     [hf:stabilityai/stablelm-2-12b]
_register(ModelConfig(
    name="stablelm-12b", family="dense", n_layers=40, d_model=5120,
    n_heads=32, n_kv_heads=8, d_ff=13824, vocab=100352,
    norm="layernorm", **_COMMON,
))

# --- starcoder2-3b [dense] 30L d=3072 24H (GQA kv=2) ff=12288 v=49152
#     GQA, RoPE, 4k sliding window [arXiv:2402.19173]
_register(ModelConfig(
    name="starcoder2-3b", family="dense", train_parallelism="fsdp", n_layers=30, d_model=3072,
    n_heads=24, n_kv_heads=2, d_ff=12288, vocab=49152,
    sliding_window=4096, mlp="gelu", norm="layernorm", **_COMMON,
))

# --- zamba2-7b [hybrid] 81L d=3584 32H (GQA kv=32) ff=14336 v=32000
#     ssm_state=64 — Mamba2 + shared attn blocks [arXiv:2411.15242]
#     Shared attention applied every 6 mamba blocks (weights shared).
_register(ModelConfig(
    name="zamba2-7b", family="hybrid", train_parallelism="fsdp", n_layers=81, d_model=3584,
    n_heads=32, n_kv_heads=32, d_ff=14336, vocab=32000,
    mixer="mamba2", ssm_state=64, ssm_head_dim=64, shared_attn_every=6,
    mlp="swiglu", **_COMMON,
))

# --- dbrx-132b [moe] 40L d=6144 48H (GQA kv=8) ff=10752 v=100352
#     16 experts top-4, fine-grained [hf:databricks/dbrx-base]
_register(ModelConfig(
    name="dbrx-132b", family="moe", n_layers=40, d_model=6144,
    n_heads=48, n_kv_heads=8, d_head=128, d_ff=10752, vocab=100352,
    mlp="moe", n_experts=16, top_k=4, d_ff_expert=10752,
    moe_impl="a2a", **_COMMON,
))

# --- deepseek-v2-lite-16b [moe] 27L d=2048 16H ff=1408 v=102400
#     MLA kv_lora=512; 2 shared + 64 routed top-6 [arXiv:2405.04434]
#     (assignment note says "160 routed"; hf config and the paper's Table 1
#      give 64 routed experts for the Lite model — we follow the hf config)
_register(ModelConfig(
    name="deepseek-v2-lite-16b", family="moe", n_layers=27, d_model=2048,
    n_heads=16, n_kv_heads=16, d_ff=1408, vocab=102400,
    mla=True, kv_lora=512, qk_rope_dims=64, qk_nope_dims=128,
    v_head_dim=128, d_head=192,
    mlp="moe", n_experts=64, top_k=6, n_shared_experts=2,
    d_ff_expert=1408, first_dense_layers=1, moe_impl="a2a", **_COMMON,
))

# --- whisper-large-v3 [audio] enc-dec 32L d=1280 20H ff=5120 v=51866
#     conv frontend is a STUB: input_specs provides frame embeddings
#     [arXiv:2212.04356]
_register(ModelConfig(
    name="whisper-large-v3", family="audio", n_layers=32, d_model=1280,
    n_heads=20, n_kv_heads=20, d_ff=5120, vocab=51866,
    enc_dec=True, n_enc_layers=32, enc_seq=1500, frontend="audio",
    mlp="gelu", norm="layernorm", use_rope=False, **_COMMON,
))

# --- llava-next-mistral-7b [vlm] 32L d=4096 32H (GQA kv=8) ff=14336 v=32000
#     anyres tiling -> vision stub supplies patch embeddings
#     [hf:llava-hf/llava-v1.6-mistral-7b-hf]
_register(ModelConfig(
    name="llava-next-mistral-7b", family="vlm", n_layers=32, d_model=4096,
    n_heads=32, n_kv_heads=8, d_head=128, d_ff=14336, vocab=32000,
    frontend="vision", n_vision_tokens=576, sliding_window=4096, **_COMMON,
))


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Same-family smoke-test variant: tiny dims, CPU-runnable."""
    kw = dict(
        n_layers=2, d_model=64, d_ff=128, vocab=512,
        compute_dtype="float32", remat=False,
        attn_chunk_q=16, attn_chunk_kv=16, rwkv_chunk=8, ssd_chunk=8,
        max_seq=256,
    )
    if cfg.mixer == "rwkv6":
        kw.update(n_heads=1, n_kv_heads=1)          # 64/64 = 1 head
    elif cfg.mixer == "mamba2":
        kw.update(n_heads=4, n_kv_heads=4, ssm_state=16, ssm_head_dim=16,
                  shared_attn_every=2 if cfg.shared_attn_every else 0,
                  d_head=None)
    else:
        q_per_kv = cfg.q_per_kv
        kw.update(n_heads=4, n_kv_heads=max(1, 4 // q_per_kv), d_head=16)
    if cfg.mlp == "moe":
        kw.update(n_experts=4, top_k=min(2, cfg.top_k), d_ff_expert=32,
                  n_shared_experts=min(1, cfg.n_shared_experts),
                  first_dense_layers=min(1, cfg.first_dense_layers))
    if cfg.mla:
        kw.update(kv_lora=32, qk_rope_dims=8, qk_nope_dims=16,
                  v_head_dim=16, d_head=24)
    if cfg.enc_dec:
        kw.update(n_enc_layers=2, enc_seq=24)
    if cfg.frontend == "vision":
        kw.update(n_vision_tokens=8)
    if cfg.sliding_window:
        kw.update(sliding_window=32)
    return cfg.replace(name=cfg.name + "-smoke", **kw)


def get_arch(name: str) -> ModelConfig:
    return ARCHS[name]
