"""Pallas TPU kernel: PB-SYM tile accumulation as an MXU contraction.

The paper's PB-SYM observation — each point's contribution factors into a
spatial disk ``Ks[X, Y]`` and a temporal bar ``Kt[T]`` — is, on TPU, a
*structure*-exposing trick: for a grid tile and a panel of P candidate
points,

    density[bx, by, bt]  =  sum_p Ks_p[bx, by] * Kt_p[bt]
                         =  reshape( Ksᵀ  @  Kt )
                            with Ks: (P, bx*by), Kt: (P, bt)

i.e. a GEMM contracting over the *point* dimension, executed on the MXU at
197 TFLOP/s instead of a scalar scatter loop. VMEM tiling:

  * the output tile (bx, by, bt) stays resident in VMEM across the whole
    point stream (the paper's DD "cache fitting" insight, made explicit);
  * candidate points arrive pre-bucketed per tile (host-side, DD-style
    overlap bucketing — ``core/bucketing.py``) and are processed in
    ``chunk``-sized panels so Ks panels fit VMEM.

Grid = (ntx, nty, ntt) output tiles; x/y/t are embarrassingly parallel
("parallel" dimension semantics; a megacore splits them).
"""
from __future__ import annotations

import functools
import warnings
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.geometry import Domain
from repro.core import kernels_math as km

# execution modes for the Pallas kernel entry points
MODES = ("auto", "interpret", "compiled")


def resolve_mode(mode: str, interpret: Optional[bool],
                 caller: str) -> bool:
    """Fold the deprecated ``interpret`` bool into ``mode`` and resolve
    ``"auto"`` against the active backend. Returns the effective
    interpret flag for ``pl.pallas_call``."""
    if interpret is not None:
        warnings.warn(
            f"{caller}(interpret=...) is deprecated; use "
            "mode='interpret' | 'compiled' | 'auto' instead",
            DeprecationWarning, stacklevel=3)
        if mode != "auto":
            raise ValueError(
                f"pass either mode={mode!r} or the deprecated interpret "
                "bool, not both")
        mode = "interpret" if interpret else "compiled"
    if mode == "auto":
        return jax.default_backend() != "tpu"
    if mode not in MODES:
        raise ValueError(f"mode must be one of {MODES}, got {mode!r}")
    return mode == "interpret"


def _kernel(
    pts_ref,    # (1, 1, 1, cap, 3) VMEM
    valid_ref,  # (1, 1, 1, cap)    VMEM
    out_ref,    # (bx, by, bt)      VMEM
    *,
    dom: Domain,
    tile: Tuple[int, int, int],
    cap: int,
    chunk: int,
    norm: float,
    ks,
    kt,
):
    bx, by, bt = tile
    ti = pl.program_id(0)
    tj = pl.program_id(1)
    tk = pl.program_id(2)

    # Voxel-center coordinates of this tile (2-D iota: TPU requires >=2D).
    ix = jax.lax.broadcasted_iota(jnp.float32, (1, bx), 1)
    iy = jax.lax.broadcasted_iota(jnp.float32, (1, by), 1)
    it = jax.lax.broadcasted_iota(jnp.float32, (1, bt), 1)
    xc = dom.ox + ((ti * bx).astype(jnp.float32) + ix + 0.5) * dom.sres
    yc = dom.oy + ((tj * by).astype(jnp.float32) + iy + 0.5) * dom.sres
    tc = dom.ot + ((tk * bt).astype(jnp.float32) + it + 0.5) * dom.tres

    nchunks = cap // chunk

    def body(c, acc):
        sl = pl.dslice(c * chunk, chunk)
        px = pts_ref[0, 0, 0, sl, 0]          # (chunk,)
        py = pts_ref[0, 0, 0, sl, 1]
        pt = pts_ref[0, 0, 0, sl, 2]
        vld = valid_ref[0, 0, 0, sl]          # (chunk,)

        u = (xc - px[:, None]) / dom.hs       # (chunk, bx)
        v = (yc - py[:, None]) / dom.hs       # (chunk, by)
        w = (tc - pt[:, None]) / dom.ht       # (chunk, bt)

        Ks = ks(u[:, :, None], v[:, None, :]) * norm      # (chunk, bx, by)
        Kt = kt(w) * vld[:, None]                          # (chunk, bt)

        # MXU contraction over the point dimension.
        panel = jax.lax.dot_general(
            Ks.reshape(chunk, bx * by),
            Kt,
            dimension_numbers=(((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                                  # (bx*by, bt)
        return acc + panel

    acc = jax.lax.fori_loop(
        0, nchunks, body, jnp.zeros((bx * by, bt), dtype=jnp.float32)
    )
    out_ref[...] = acc.reshape(bx, by, bt)


def stkde_tiles_pallas(
    pts_tiles: jnp.ndarray,    # (ntx, nty, ntt, cap, 3) f32
    valid_tiles: jnp.ndarray,  # (ntx, nty, ntt, cap) f32
    dom: Domain,
    tile: Tuple[int, int, int],
    cap: int,
    n_total: int,
    chunk: int = 256,
    ks: km.SpatialKernel = km.DEFAULT_KS,
    kt: km.TemporalKernel = km.DEFAULT_KT,
    interpret: Optional[bool] = None,
    mode: str = "auto",
) -> jnp.ndarray:
    """Padded density grid (ntx*bx, nty*by, ntt*bt).

    ``mode`` selects kernel execution: ``"compiled"`` lowers through
    Mosaic (TPU), ``"interpret"`` runs the kernel body under the Pallas
    interpreter (bitwise-faithful, any backend, slow), ``"auto"``
    (default) picks compiled on TPU and interpret elsewhere. The
    ``interpret`` bool is deprecated — it maps True -> "interpret",
    False -> "compiled" with a DeprecationWarning.
    """
    return _stkde_tiles_pallas(
        pts_tiles, valid_tiles, dom, tile, cap, n_total, chunk, ks, kt,
        resolve_mode(mode, interpret, "stkde_tiles_pallas"),
    )


@functools.partial(
    jax.jit,
    static_argnames=(
        "dom", "tile", "cap", "chunk", "n_total", "ks", "kt", "interpret"
    ),
)
def _stkde_tiles_pallas(
    pts_tiles: jnp.ndarray,
    valid_tiles: jnp.ndarray,
    dom: Domain,
    tile: Tuple[int, int, int],
    cap: int,
    n_total: int,
    chunk: int = 256,
    ks: km.SpatialKernel = km.DEFAULT_KS,
    kt: km.TemporalKernel = km.DEFAULT_KT,
    interpret: bool = True,
) -> jnp.ndarray:
    ntx, nty, ntt = pts_tiles.shape[:3]
    bx, by, bt = tile
    chunk = min(chunk, cap)
    assert cap % chunk == 0, (cap, chunk)
    norm = km.normalization(n_total, dom.hs, dom.ht)

    kernel = functools.partial(
        _kernel, dom=dom, tile=tile, cap=cap, chunk=chunk,
        norm=norm, ks=ks, kt=kt,
    )
    grid = (ntx, nty, ntt)
    out_shape = jax.ShapeDtypeStruct((ntx * bx, nty * by, ntt * bt),
                                     jnp.float32)
    fn = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1, cap, 3), lambda i, j, k: (i, j, k, 0, 0)),
            pl.BlockSpec((1, 1, 1, cap), lambda i, j, k: (i, j, k, 0)),
        ],
        out_specs=pl.BlockSpec((bx, by, bt), lambda i, j, k: (i, j, k)),
        out_shape=out_shape,
        interpret=interpret,
    )
    return fn(pts_tiles, valid_tiles)
