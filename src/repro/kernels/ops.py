"""Jit'd public wrappers around the Pallas STKDE kernels.

``stkde_tiled(points, dom)`` is the TPU performance path for single-device
STKDE: host-side overlap bucketing -> Pallas tile-GEMM kernel -> slice to the
domain grid. On CPU it runs the kernel in interpret mode (bitwise-faithful to
the kernel body, slow) — use ``core.pb`` for fast CPU execution.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.geometry import Domain
from repro.core import bucketing
from repro.core import kernels_math as km
from . import ref as _ref
from .stkde_tile import stkde_tiles_pallas


def default_tile(dom: Domain) -> Tuple[int, int, int]:
    """Tile shape tuned for the TPU memory hierarchy.

    * bx, by multiples of 8 with bx*by a multiple of 256 keeps the GEMM's
      output panel MXU-aligned (bx*by plays the "M" dimension).
    * bt (the "N" dimension) padded to >= 8; temporal bandwidths are small so
      bt stays modest and the accumulator (bx*by*bt*4B) fits VMEM easily.
    """
    bx = int(min(bucketing.round_up(dom.Gx, 8), 32))
    by = int(min(bucketing.round_up(dom.Gy, 8), 32))
    bt = int(min(bucketing.round_up(dom.Gt, 8), 16))
    return (bx, by, bt)


def stkde_tiled(
    points: np.ndarray,
    dom: Domain,
    tile: Optional[Tuple[int, int, int]] = None,
    cap: Optional[int] = None,
    chunk: int = 256,
    ks: km.SpatialKernel = km.DEFAULT_KS,
    kt: km.TemporalKernel = km.DEFAULT_KT,
    interpret: Optional[bool] = None,
    use_ref: bool = False,
    mode: str = "auto",
) -> jnp.ndarray:
    """STKDE density grid via the tiled PB-SYM GEMM kernel.

    ``mode`` ("auto" | "interpret" | "compiled") selects how the Pallas
    kernel executes — see ``stkde_tiles_pallas``. ``"auto"`` compiles on
    TPU and interprets elsewhere. The three-state ``interpret`` bool is
    deprecated (True -> "interpret", False -> "compiled"); passing it
    emits a DeprecationWarning.
    """
    pts = np.asarray(points, dtype=np.float32)
    n = len(pts)
    if tile is None:
        tile = default_tile(dom)
    b = bucketing.bucket_points_overlap(pts, dom, tile, cap=cap)
    cap_eff = bucketing.round_up(b.cap, min(chunk, bucketing.round_up(b.cap, 8)))
    if cap_eff != b.cap:
        pad = cap_eff - b.cap
        b_points = np.pad(b.points, ((0, 0),) * 3 + ((0, pad), (0, 0)))
        b_valid = np.pad(b.valid, ((0, 0),) * 3 + ((0, pad),))
    else:
        b_points, b_valid = b.points, b.valid
    chunk_eff = min(chunk, cap_eff)
    # make chunk divide cap
    while cap_eff % chunk_eff:
        chunk_eff //= 2
    args = (
        jnp.asarray(b_points),
        jnp.asarray(b_valid.astype(np.float32)),
    )
    if use_ref:
        padded = _ref.stkde_tiles_ref(*args, dom, tile, n, ks, kt)
    else:
        padded = stkde_tiles_pallas(
            *args, dom, tile, cap_eff, n, chunk_eff, ks, kt,
            interpret=interpret, mode=mode,
        )
    return padded[: dom.Gx, : dom.Gy, : dom.Gt]
