"""Pallas TPU kernels for the STKDE compute hot-spot.

stkde_tile.py — PB-SYM tile accumulation as an MXU GEMM (pallas_call +
                explicit BlockSpec VMEM tiling)
ops.py        — jit'd public wrappers (bucketing + kernel + slice)
ref.py        — pure-jnp oracles for allclose testing
"""
from .ops import stkde_tiled, default_tile
from .stkde_tile import stkde_tiles_pallas
from .ref import stkde_tiles_ref

__all__ = [
    "stkde_tiled",
    "default_tile",
    "stkde_tiles_pallas",
    "stkde_tiles_ref",
]
