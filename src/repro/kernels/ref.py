"""Pure-jnp oracles for the Pallas STKDE kernels.

``stkde_tiles_ref`` computes exactly what the tile kernel computes — per-tile
density via the PB-SYM separable contraction — with plain jnp ops. It is the
allclose target for every kernel sweep test, and is itself cross-validated
against ``core.pb``/``core.vb`` (three independent formulations).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.geometry import Domain
from repro.core import kernels_math as km


@functools.partial(
    jax.jit,
    static_argnames=("dom", "tile", "n_total", "ks", "kt"),
)
def stkde_tiles_ref(
    pts_tiles: jnp.ndarray,   # (ntx, nty, ntt, cap, 3) f32, overlap-bucketed
    valid_tiles: jnp.ndarray,  # (ntx, nty, ntt, cap) f32 {0, 1}
    dom: Domain,
    tile: tuple,
    n_total: int,
    ks: km.SpatialKernel = km.DEFAULT_KS,
    kt: km.TemporalKernel = km.DEFAULT_KT,
) -> jnp.ndarray:
    """Padded density grid (ntx*bx, nty*by, ntt*bt); slice to dom.grid_shape."""
    bx, by, bt = tile
    ntx, nty, ntt = pts_tiles.shape[:3]
    norm = km.normalization(n_total, dom.hs, dom.ht)

    ix = jnp.arange(bx, dtype=jnp.float32)
    iy = jnp.arange(by, dtype=jnp.float32)
    it = jnp.arange(bt, dtype=jnp.float32)

    def one_tile(ti, tj, tk, pts, vld):
        xc = dom.ox + ((ti * bx + ix) + 0.5) * dom.sres
        yc = dom.oy + ((tj * by + iy) + 0.5) * dom.sres
        tc = dom.ot + ((tk * bt + it) + 0.5) * dom.tres
        u = (xc[None, :] - pts[:, 0:1]) / dom.hs         # (cap, bx)
        v = (yc[None, :] - pts[:, 1:2]) / dom.hs         # (cap, by)
        w = (tc[None, :] - pts[:, 2:3]) / dom.ht         # (cap, bt)
        Ks = ks(u[:, :, None], v[:, None, :]) * norm     # (cap, bx, by)
        Kt = kt(w) * vld[:, None]                        # (cap, bt)
        return jnp.einsum("pxy,pt->xyt", Ks, Kt)

    f = jax.vmap(
        jax.vmap(
            jax.vmap(one_tile, in_axes=(None, None, 0, 0, 0)),
            in_axes=(None, 0, None, 0, 0),
        ),
        in_axes=(0, None, None, 0, 0),
    )
    tiles = f(
        jnp.arange(ntx, dtype=jnp.float32),
        jnp.arange(nty, dtype=jnp.float32),
        jnp.arange(ntt, dtype=jnp.float32),
        pts_tiles,
        valid_tiles,
    )                                                    # (ntx,nty,ntt,bx,by,bt)
    return jnp.transpose(tiles, (0, 3, 1, 4, 2, 5)).reshape(
        ntx * bx, nty * by, ntt * bt
    )
