"""Batched serving engine: continuous batching (slot-swap decode) with a
bucketed reference path.

Serving path used by examples/serve_lm.py and the decode dry-run cells:

  * ``make_serve_step(cfg)``   — the pure (params, state, token) -> (logits,
    state) decode function the dry-run lowers (one new token against a
    seq_len KV cache; the ``decode_*`` / ``long_*`` shape cells).
  * ``ServingEngine``          — with ``EngineConfig.continuous_batching``
    (the default) the engine runs a fixed pool of ``max_batch`` decode
    slots with per-row KV-cache positions (``DecodeState.step`` as a (B,)
    vector): a row that hits EOS / ``max_new`` / its deadline is swapped
    out immediately and the next queued request is prefilled into the
    freed slot *mid-decode* (``models.model.prefill(..., state=, slot=)``),
    so no slot idles while work is queued — the same no-straggler
    scheduling argument the paper makes for spatio-temporal tiles.
    ``continuous_batching=False`` keeps the bucketed reference oracle:
    same-length buckets, lockstep decode, finished rows idle until the
    bucket drains. Greedy decode is token-identical across the two paths
    (per-row masks make every row's math independent of its neighbors),
    which is what the continuous-batching tests assert.

Scheduler loop (continuous path; docs/serving.md has the diagram)::

    while queued or occupied:
        retire rows at EOS / max_new / deadline   -> RequestResult
        prefill queued requests into free slots   (serve.swap_s)
        one masked decode step over the pool      (serve.decode_token_s)

Resilience contract (docs/resilience.md): ``submit`` validates prompts and
enforces bounded admission (``EngineConfig.max_queue``, typed
``AdmissionError`` + ``serve.rejected`` counter); ``run`` never raises for
a per-request failure. In the continuous path the retry/degrade unit is
per-slot: a failing slot prefill is retried under ``EngineConfig.retry``
and then fails only that request; a failing decode step is retried in
place and, when exhausted, fails only the rows occupied at that moment —
the pool keeps serving the rest of the queue. Every admitted uid ends in
a terminal ``RequestResult`` (ok / degraded / typed failure). Sampling
keys derive from ``jax.random.fold_in(base_key, uid)`` then the per-token
position, so retries and solo-degrade reruns resample identical tokens.

Observability: ``serve.queue_wait_s`` (observed exactly once per request,
at first service attempt), ``serve.swap_s``, ``serve.slot_occupancy``,
``serve.slot_idle_frac``, ``serve.tokens_per_s`` (wall clock, swaps
included) and ``serve.decode_tokens_per_s`` (decode-step time only).
"""
from __future__ import annotations

import dataclasses
import time
from collections import defaultdict, deque
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.models import model as model_lib
from repro.models.model import DecodeState
from repro.resilience import (
    AdmissionError,
    NonFiniteOutputError,
    ReproValidationError,
    RetryPolicy,
    faults,
    with_retry,
)


def make_serve_step(cfg):
    """One-token decode step (jit/pjit target for the dry-run)."""

    def serve_step(params, state: DecodeState, token):
        return model_lib.decode_step(cfg, params, token, state)

    return serve_step


def make_prefill(cfg, max_seq: int):
    def prefill_fn(params, tokens, **kw):
        return model_lib.prefill(cfg, params, tokens, max_seq=max_seq, **kw)

    return prefill_fn


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # (S,) int32
    max_new: int = 32
    out: Optional[np.ndarray] = None
    t_submit: float = 0.0         # perf_counter at submit(); queue-wait base
    deadline: Optional[float] = None   # perf_counter absolute deadline
    qw_seen: bool = False         # queue wait observed (once per request)


@dataclasses.dataclass
class RequestResult:
    """Terminal status of one served request.

    Exactly one of three shapes (the engine's completion guarantee):
    ``ok`` (full generation), ``degraded`` (partial/retried generation,
    ``reason`` says why), or failed (``ok=False`` with a typed ``reason``
    — never an unhandled exception).
    """

    uid: int
    tokens: np.ndarray
    ok: bool = True
    degraded: bool = False
    reason: str = ""
    attempts: int = 1


@dataclasses.dataclass
class EngineConfig:
    max_batch: int = 8
    max_seq: int = 512
    temperature: float = 0.0      # 0 = greedy
    eos_id: int = -1              # -1 = never stop on token
    seed: int = 0
    continuous_batching: bool = True   # slot-swap decode; False = bucketed
    # --- resilience ---
    max_queue: int = 256          # bounded admission; 0 = unbounded
    request_timeout_s: Optional[float] = None   # 0 = expire immediately
    retry: RetryPolicy = dataclasses.field(
        default_factory=lambda: RetryPolicy(max_attempts=3,
                                            base_delay_s=0.002,
                                            max_delay_s=0.05)
    )


def _blank_stats(mode: str) -> Dict:
    return {
        "mode": mode,
        "wall_s": 0.0,
        "decode_s": 0.0,
        "n_tokens": 0,
        "decode_steps": 0,
        "slot_steps": 0,          # decode_steps * pool width
        "active_slot_steps": 0,   # slot-steps that produced a kept token
        "swaps": 0,
        "queue_wait_s": [],
    }


class ServingEngine:
    def __init__(self, cfg, params, ecfg: EngineConfig):
        if ecfg.request_timeout_s is not None and ecfg.request_timeout_s < 0:
            raise ReproValidationError(
                f"request_timeout_s must be >= 0 or None: "
                f"{ecfg.request_timeout_s}"
            )
        if ecfg.max_batch < 1:
            raise ReproValidationError(
                f"max_batch must be >= 1: {ecfg.max_batch}"
            )
        self.cfg = cfg
        self.params = params
        self.ecfg = ecfg
        self.queue: List[Request] = []
        self.done: Dict[int, np.ndarray] = {}
        self.results: Dict[int, RequestResult] = {}
        self.last_stats: Dict = _blank_stats("idle")
        self._prefill = jax.jit(make_prefill(cfg, ecfg.max_seq))
        self._prefill_slot = jax.jit(
            lambda params, tokens, state, slot: model_lib.prefill(
                cfg, params, tokens, max_seq=ecfg.max_seq,
                state=state, slot=slot,
            )
        )
        self._step = jax.jit(make_serve_step(cfg))
        self._base_key = jax.random.PRNGKey(ecfg.seed)
        if ecfg.temperature > 0:
            base, temp = self._base_key, ecfg.temperature

            def sampler(logits, uids, counts):
                def one(row_logits, uid, count):
                    k = jax.random.fold_in(
                        jax.random.fold_in(base, uid), count)
                    return jax.random.categorical(k, row_logits / temp)

                return jax.vmap(one)(logits, uids, counts)

            self._sample_fn = jax.jit(sampler)
        # continuous batching needs decoder-only states (slot-swap has no
        # per-row encoder output scatter); whisper-style archs fall back
        self._continuous = (ecfg.continuous_batching
                            and not getattr(cfg, "enc_dec", False))

    # ------------------------------------------------------------- submit
    def _validate_prompt(self, prompt: np.ndarray) -> np.ndarray:
        p = np.asarray(prompt)
        if p.ndim != 1 or len(p) == 0:
            raise ReproValidationError(
                f"prompt must be a non-empty 1-D token array; got shape "
                f"{p.shape}"
            )
        if len(p) > self.ecfg.max_seq:
            raise ReproValidationError(
                f"prompt length {len(p)} exceeds max_seq "
                f"{self.ecfg.max_seq}"
            )
        if not np.issubdtype(p.dtype, np.integer):
            if not np.all(np.isfinite(p)) or np.any(p != np.floor(p)):
                raise ReproValidationError(
                    "prompt tokens must be integers (got non-finite or "
                    "fractional values)"
                )
        vocab = getattr(self.cfg, "vocab", None)
        if np.any(p < 0) or (vocab is not None and np.any(p >= vocab)):
            raise ReproValidationError(
                f"prompt tokens outside [0, {vocab})"
            )
        return p.astype(np.int32)

    def submit(self, uid: int, prompt: np.ndarray, max_new: int = 32):
        """Enqueue a request. Raises ``ReproValidationError`` on malformed
        input and ``AdmissionError`` when the queue is full."""
        if max_new <= 0:
            raise ReproValidationError(f"max_new must be positive: {max_new}")
        p = self._validate_prompt(prompt)
        if self.ecfg.max_queue > 0 and len(self.queue) >= self.ecfg.max_queue:
            obs.counter("serve.rejected").inc()
            raise AdmissionError(
                "queue_full",
                f"admission queue full ({len(self.queue)}/"
                f"{self.ecfg.max_queue}); retry after run()",
            )
        obs.counter("serve.requests").inc()
        now = time.perf_counter()
        # timeout 0 means "expire immediately", not "no timeout" — only
        # None disables the deadline
        dl = (now + self.ecfg.request_timeout_s
              if self.ecfg.request_timeout_s is not None else None)
        self.queue.append(
            Request(uid=uid, prompt=p, max_new=max_new, t_submit=now,
                    deadline=dl)
        )

    # ---------------------------------------------------------------- run
    def run(self) -> Dict[int, np.ndarray]:
        """Serve everything in the queue; returns uid -> generated tokens.

        Completion guarantee: every queued uid appears in the result (and
        in ``self.results`` with full status) — failed/expired requests
        map to an empty token array rather than raising.
        """
        reqs, self.queue = self.queue, []
        self.results = {}
        self.last_stats = _blank_stats(
            "continuous" if self._continuous else "bucketed")
        t0 = time.perf_counter()
        if self._continuous:
            self._run_continuous(reqs)
        else:
            buckets = defaultdict(list)
            for r in reqs:
                buckets[len(r.prompt)].append(r)
            for _, bucket in sorted(buckets.items()):
                for i in range(0, len(bucket), self.ecfg.max_batch):
                    self._serve_bucket(bucket[i : i + self.ecfg.max_batch])
        st = self.last_stats
        st["wall_s"] = time.perf_counter() - t0
        if st["slot_steps"]:
            obs.gauge("serve.slot_idle_frac").set(
                1.0 - st["active_slot_steps"] / st["slot_steps"])
        if st["wall_s"] > 0:
            obs.gauge("serve.tokens_per_s").set(
                st["n_tokens"] / st["wall_s"])
        if st["decode_s"] > 0:
            obs.gauge("serve.decode_tokens_per_s").set(
                st["n_tokens"] / st["decode_s"])
        obs.counter("serve.tokens").inc(st["n_tokens"])
        out, self.done = self.done, {}
        return out

    def run_detailed(self) -> Dict[int, RequestResult]:
        """Like ``run`` but returns the full per-request status map."""
        self.run()
        return self.results

    # --------------------------------------------------------- shared bits
    def _observe_queue_wait(self, r: Request) -> None:
        """Queue wait is observed exactly once per request, at its first
        service attempt — retries and solo-degrade reruns must not
        re-observe it (they would inflate p95/p99 under fault injection)."""
        if r.qw_seen or r.t_submit <= 0:
            return
        r.qw_seen = True
        w = max(time.perf_counter() - r.t_submit, 0.0)
        obs.histogram("serve.queue_wait_s").observe(w)
        self.last_stats["queue_wait_s"].append(w)

    def _sample(self, logits, uids, counts) -> jnp.ndarray:
        """Per-request sampling: row i's key is fold_in(fold_in(base,
        uid_i), count_i), a pure function of (seed, uid, position) — no
        engine-level RNG stream, so fault-history cannot shift tokens."""
        if self.ecfg.temperature <= 0:
            return jnp.argmax(logits, axis=-1)
        return self._sample_fn(
            logits,
            jnp.asarray(np.asarray(uids, np.uint32)),
            jnp.asarray(np.asarray(counts, np.uint32)),
        )

    @staticmethod
    def _check_logits(logits):
        """Fault-site output validation: poisoned logits must not silently
        become argmax(NaN)=0 tokens."""
        host = np.asarray(logits)
        if not np.isfinite(host).all():
            raise NonFiniteOutputError("serve: non-finite logits")
        return host

    def _fail(self, r: Request, exc: BaseException, attempts: int,
              tokens: Optional[List[int]] = None) -> None:
        obs.counter("serve.failed").inc()
        toks = np.asarray(tokens or [], np.int32)
        self.results[r.uid] = RequestResult(
            uid=r.uid, tokens=toks, ok=False, degraded=True,
            attempts=attempts, reason=f"{type(exc).__name__}: {exc}",
        )
        self.done[r.uid] = toks

    # ------------------------------------------------- continuous batching
    def _run_continuous(self, reqs: List[Request]) -> None:
        """Slot-swap scheduler: fixed pool of ``max_batch`` decode slots,
        per-row cache positions, mid-decode prefill into freed slots."""
        B = self.ecfg.max_batch
        dt = jnp.dtype(self.cfg.compute_dtype)
        state = model_lib.init_decode_state(
            self.cfg, B, self.ecfg.max_seq, dt, per_row=True)
        pending = deque(reqs)
        slots: List[Optional[Request]] = [None] * B
        gen: List[List[int]] = [[] for _ in range(B)]
        attempts = [1] * B
        retried = [False] * B
        last_tok = np.zeros(B, np.int32)
        uids = np.zeros(B, np.int64)
        st = self.last_stats
        decode_h = obs.histogram("serve.decode_token_s")
        swap_h = obs.histogram("serve.swap_s")
        eos = self.ecfg.eos_id

        def occupied() -> List[int]:
            return [i for i in range(B) if slots[i] is not None]

        def retire(i: int, ok: bool = True, reason: str = "",
                   exc: Optional[BaseException] = None) -> None:
            r = slots[i]
            slots[i] = None
            toks = gen[i][: r.max_new]
            gen[i] = []
            if not ok:
                self._fail(r, exc, attempts[i], tokens=toks)
                return
            degraded = bool(reason) or retried[i]
            self.results[r.uid] = RequestResult(
                uid=r.uid, tokens=np.asarray(toks, np.int32), ok=True,
                degraded=degraded, attempts=attempts[i],
                reason=reason or ("retried" if retried[i] else ""),
            )
            self.done[r.uid] = self.results[r.uid].tokens

        def retire_finished() -> None:
            now = time.perf_counter()
            for i in occupied():
                r = slots[i]
                if len(gen[i]) >= r.max_new:
                    retire(i)
                elif (r.deadline is not None and now > r.deadline
                        and (eos < 0 or eos not in gen[i])):
                    obs.counter("serve.deadline_truncated").inc()
                    retire(i, reason="deadline_truncated")

        with obs.span("serve.continuous", batch=B, n_requests=len(reqs)):
            while pending or occupied():
                retire_finished()
                # ---- swap in: prefill queued requests into free slots
                for i in range(B):
                    if slots[i] is not None or not pending:
                        continue
                    r = pending.popleft()
                    self._observe_queue_wait(r)
                    t_sw = time.perf_counter()
                    swapped = self._swap_in(r, i, state)
                    swap_h.observe(time.perf_counter() - t_sw)
                    st["swaps"] += 1
                    if swapped is None:      # typed failure already logged
                        continue
                    state, first, n_att = swapped
                    slots[i] = r
                    gen[i] = [first]
                    last_tok[i] = first
                    uids[i] = r.uid
                    attempts[i] = n_att
                    retried[i] = n_att > 1
                    st["n_tokens"] += 1
                retire_finished()            # max_new==1 / expired deadlines
                occ = occupied()
                obs.gauge("serve.slot_occupancy").set(len(occ) / B)
                if not occ:
                    if pending:
                        continue
                    break
                # ---- one masked decode step over the whole pool
                tok = jnp.asarray(last_tok[:, None])
                counts = np.fromiter((len(g) for g in gen), np.int64, B)
                cur_state = state

                def step_attempt() -> Tuple[DecodeState, np.ndarray]:
                    faults.fault_point("serve.decode")
                    logits, new_state = self._step(
                        self.params, cur_state, tok)
                    logits = faults.poison("serve.decode", logits)
                    nxt = np.asarray(self._sample(logits[:, -1], uids,
                                                  counts))
                    host = np.asarray(logits[:, -1])
                    if not np.isfinite(host[occ]).all():
                        raise NonFiniteOutputError(
                            "serve: non-finite logits")
                    return new_state, nxt

                n_att = [1]

                def bump(_a, _e, _d):
                    n_att[0] += 1
                    for i in occ:
                        attempts[i] += 1
                        retried[i] = True

                t_dec = time.perf_counter()
                try:
                    state, nxt = with_retry(
                        step_attempt, policy=self.ecfg.retry,
                        site="serve.decode", on_retry=bump,
                    )
                except Exception as e:  # noqa: BLE001 — per-slot degrade
                    obs.counter("serve.step_failed").inc()
                    for i in occ:
                        r, toks = slots[i], gen[i]
                        slots[i], gen[i] = None, []
                        self._fail(r, e, attempts[i], tokens=toks)
                    continue
                dt_step = time.perf_counter() - t_dec
                decode_h.observe(dt_step)
                st["decode_s"] += dt_step
                st["decode_steps"] += 1
                st["slot_steps"] += B
                st["active_slot_steps"] += len(occ)
                for i in occ:
                    t = int(nxt[i])
                    gen[i].append(t)
                    last_tok[i] = t
                    st["n_tokens"] += 1
                    if t == eos and len(gen[i]) > 1:
                        retire(i)

    def _swap_in(self, r: Request, slot: int, state: DecodeState):
        """Prefill one request into pool row ``slot`` (retried under the
        engine policy). Returns (new_state, first_token, attempts) or None
        after recording a typed failure — never raises."""
        n_att = [1]

        def bump(_a, _e, _d):
            n_att[0] += 1

        prompt = jnp.asarray(r.prompt[None])
        slot_ix = jnp.asarray(slot, jnp.int32)

        def attempt():
            with obs.span("serve.prefill", slot=slot, seq=len(r.prompt)) \
                    as sp:
                faults.fault_point("serve.prefill")
                logits, new_state = self._prefill_slot(
                    self.params, prompt, state, slot_ix)
                logits = faults.poison("serve.prefill", logits)
                jax.block_until_ready(logits)
            obs.histogram("serve.prefill_s").observe(sp.duration_s)
            self._check_logits(logits[:, -1])
            return logits, new_state

        try:
            logits, new_state = with_retry(
                attempt, policy=self.ecfg.retry, site="serve.prefill",
                on_retry=bump,
            )
        except Exception as e:  # noqa: BLE001 — per-slot degrade
            self._fail(r, e, n_att[0])
            return None
        first = int(np.asarray(
            self._sample(logits[:, -1], [r.uid], [0]))[0])
        return new_state, first, n_att[0]

    # ------------------------------------------------- bucketed reference
    def _serve_bucket(self, reqs: List[Request]):
        """Retry-or-degrade wrapper: bucket retried whole, then failing
        requests re-run solo, and final stragglers are marked failed —
        this method never raises for per-request faults."""
        attempts = 1

        def bump(_a, _e, _d):
            nonlocal attempts
            attempts += 1

        for r in reqs:
            self._observe_queue_wait(r)
        try:
            gen = with_retry(
                lambda: self._run_bucket(reqs),
                policy=self.ecfg.retry,
                site="serve.bucket",
                on_retry=bump,
            )
            self._finish(reqs, gen, attempts=attempts,
                         degraded=attempts > 1,
                         reason="retried" if attempts > 1 else "")
            return
        except Exception as e:  # noqa: BLE001 — degrade path below
            obs.counter("serve.bucket_failed").inc()
            last = e
        if len(reqs) > 1:
            # degrade: the bucket keeps failing as a batch — serve each
            # request alone so one poisoned row cannot sink its neighbors
            for r in reqs:
                self._serve_bucket([r])
            for r in reqs:
                res = self.results[r.uid]
                if res.ok and not res.degraded:
                    res.degraded = True
                    res.reason = "bucket_degraded_to_solo"
            return
        self._fail(reqs[0], last, attempts)

    def _finish(self, reqs, gen, attempts=1, degraded=False, reason=""):
        for r_i, r in enumerate(reqs):
            toks = np.asarray(gen[r_i][: r.max_new], np.int32)
            timed_out = (r.deadline is not None
                         and len(toks) < r.max_new
                         and time.perf_counter() > r.deadline
                         and (self.ecfg.eos_id < 0
                              or self.ecfg.eos_id not in toks.tolist()))
            self.results[r.uid] = RequestResult(
                uid=r.uid, tokens=toks, ok=True,
                degraded=degraded or timed_out,
                attempts=attempts,
                reason="deadline_truncated" if timed_out else reason,
            )
            self.done[r.uid] = toks

    def _run_bucket(self, reqs: List[Request]) -> List[List[int]]:
        """One attempt at a bucket; pure w.r.t. engine state so retries
        can re-run it from scratch (results land via ``_finish``)."""
        B = len(reqs)
        uids = [r.uid for r in reqs]
        st = self.last_stats
        with obs.span("serve.bucket", batch=B, seq=len(reqs[0].prompt)):
            prompts = jnp.asarray(np.stack([r.prompt for r in reqs]))
            with obs.span("serve.prefill") as sp:
                faults.fault_point("serve.prefill")
                logits, state = self._prefill(self.params, prompts)
                logits = faults.poison("serve.prefill", logits)
                jax.block_until_ready(logits)
            obs.histogram("serve.prefill_s").observe(sp.duration_s)
            self._check_logits(logits[:, -1])
            max_new = max(r.max_new for r in reqs)
            tok = self._sample(logits[:, -1], uids, [0] * B)[:, None]
            active = np.ones(B, bool)
            gen: List[List[int]] = [[] for _ in range(B)]
            for r_i in range(B):
                gen[r_i].append(int(tok[r_i, 0]))
            st["n_tokens"] += B
            decode_h = obs.histogram("serve.decode_token_s")
            for _ in range(max_new - 1):
                t0 = time.perf_counter()
                faults.fault_point("serve.decode")
                logits, state = self._step(self.params, state, tok)
                logits = faults.poison("serve.decode", logits)
                self._check_logits(logits[:, -1])
                counts = [len(g) for g in gen]
                tok = self._sample(logits[:, -1], uids, counts)[:, None]
                host = np.asarray(tok[:, 0])   # device sync
                dt_step = time.perf_counter() - t0
                decode_h.observe(dt_step)
                st["decode_s"] += dt_step
                st["decode_steps"] += 1
                st["slot_steps"] += B
                now = time.perf_counter()
                for r_i in range(B):
                    if not active[r_i]:
                        continue
                    if len(gen[r_i]) >= reqs[r_i].max_new:
                        active[r_i] = False
                        continue
                    if (reqs[r_i].deadline is not None
                            and now > reqs[r_i].deadline):
                        # per-request timeout: stop generating for this
                        # row; _finish tags the partial result degraded
                        obs.counter("serve.deadline_truncated").inc()
                        active[r_i] = False
                        continue
                    t = int(host[r_i])
                    gen[r_i].append(t)
                    st["n_tokens"] += 1
                    st["active_slot_steps"] += 1
                    if t == self.ecfg.eos_id:
                        active[r_i] = False
                if not active.any():
                    break
        return gen


# ------------------------------------------------- STKDE partial answers
@dataclasses.dataclass
class PartialGridAnswer:
    """A degraded STKDE answer served from a salvaged progress journal.

    The lowest degrade rung for density queries: when a chunked run died
    mid-way (docs/resilience.md "Resumable execution"), the journal's
    newest verified accumulator snapshot already holds the exact density
    contribution of every completed chunk — serve that instead of
    failing, tagged with how much of the point set it covers.
    """

    grid: np.ndarray          # float64 accumulator (optionally rescaled)
    coverage: float           # fraction of points folded in, in (0, 1]
    chunks: int               # completed chunks behind the answer
    n_total: int              # global point count of the full run
    journal_path: str
    rescaled: bool


def stkde_partial_answer(journal_path: str,
                         rescale: bool = True) -> PartialGridAnswer:
    """Answer a density query from the salvaged state of ``journal_path``.

    ``rescale=True`` divides the partial accumulator by the coverage
    fraction — an unbiased estimate of the full-run grid when chunks are
    exchangeable (the synthetic streams draw i.i.d. chunks), analogous to
    the coreset estimate of Zheng et al. Raises a typed
    ``ReproValidationError`` when the journal holds nothing salvageable —
    callers then fall through to the coarsen/subsample degrade ladder.
    """
    from repro.resilience.errors import ReproValidationError
    from repro.resilience.journal import ProgressJournal

    salvage = ProgressJournal(journal_path).replay()
    if salvage.meta is None or salvage.grid is None:
        raise ReproValidationError(
            f"no salvageable chunks in journal {journal_path!r}: cannot "
            "serve a partial answer"
        )
    n_total = int(salvage.meta.get("meta", {}).get("n_total", 0))
    stop = salvage.ranges[salvage.chunk_id][1]
    coverage = stop / n_total if n_total else 0.0
    grid = np.array(salvage.grid, dtype=np.float64)
    if rescale and coverage > 0:
        grid /= coverage
    obs.counter("serve.partial_answers").inc()
    with obs.span("serve.partial_answer", coverage=round(coverage, 4),
                  chunks=salvage.chunk_id + 1):
        return PartialGridAnswer(
            grid=grid, coverage=coverage, chunks=salvage.chunk_id + 1,
            n_total=n_total, journal_path=str(journal_path),
            rescaled=bool(rescale),
        )


def cache_bytes(cfg, batch: int, seq: int) -> int:
    """KV-cache HBM footprint for reports/planning (bf16)."""
    if cfg.mixer == "attn" and cfg.mla:
        per_tok = cfg.kv_lora + cfg.qk_rope_dims
        return cfg.n_layers * batch * seq * per_tok * 2
    if cfg.mixer == "attn":
        per_tok = 2 * cfg.n_kv_heads * cfg.head_dim
        return cfg.n_layers * batch * seq * per_tok * 2
    state = 0
    if cfg.mixer == "mamba2":
        state = cfg.n_layers * batch * (
            cfg.n_ssm_heads * cfg.ssm_head_dim * cfg.ssm_state * 4
            + (cfg.ssm_conv - 1) * (cfg.d_inner_ssm + 2 * cfg.ssm_groups
                                    * cfg.ssm_state) * 2
        )
    if cfg.mixer == "rwkv6":
        H = cfg.d_model // 64
        state = cfg.n_layers * batch * (H * 64 * 64 * 4 + 2 * cfg.d_model * 2)
    if cfg.shared_attn_every > 0:
        state += (cfg.attn_sites * batch * seq
                  * 2 * cfg.n_kv_heads * cfg.head_dim * 2)
    return state
