"""Batched serving engine: bucketed prefill + masked decode.

Serving path used by examples/serve_lm.py and the decode dry-run cells:

  * ``make_serve_step(cfg)``   — the pure (params, state, token) -> (logits,
    state) decode function the dry-run lowers (one new token against a
    seq_len KV cache; the ``decode_*`` / ``long_*`` shape cells).
  * ``ServingEngine``          — groups queued requests into same-length
    buckets (no padding-token infidelity), prefills each bucket as a batch,
    then decodes with a per-row active mask, greedy or temperature sampling,
    EOS + max-token stopping. Finished rows idle until the bucket drains
    (continuous batching slot-swap is a documented extension point — it
    needs per-row cache indices, see DESIGN.md).

Resilience contract (docs/resilience.md): ``submit`` validates prompts and
enforces bounded admission (``EngineConfig.max_queue``, typed
``AdmissionError`` + ``serve.rejected`` counter); ``run`` never raises for
a per-request failure — each bucket is retried under
``EngineConfig.retry``, failing requests re-run solo, and a request that
still cannot complete (or overran ``request_timeout_s``) yields a
``RequestResult`` with ``degraded=True``/``ok=False`` and a typed reason.
"""
from __future__ import annotations

import dataclasses
import time
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.models import model as model_lib
from repro.models.model import DecodeState
from repro.resilience import (
    AdmissionError,
    NonFiniteOutputError,
    ReproValidationError,
    RetryPolicy,
    faults,
    with_retry,
)


def make_serve_step(cfg):
    """One-token decode step (jit/pjit target for the dry-run)."""

    def serve_step(params, state: DecodeState, token):
        return model_lib.decode_step(cfg, params, token, state)

    return serve_step


def make_prefill(cfg, max_seq: int):
    def prefill_fn(params, tokens, **kw):
        return model_lib.prefill(cfg, params, tokens, max_seq=max_seq, **kw)

    return prefill_fn


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # (S,) int32
    max_new: int = 32
    out: Optional[np.ndarray] = None
    t_submit: float = 0.0         # perf_counter at submit(); queue-wait base
    deadline: Optional[float] = None   # perf_counter absolute deadline


@dataclasses.dataclass
class RequestResult:
    """Terminal status of one served request.

    Exactly one of three shapes (the engine's completion guarantee):
    ``ok`` (full generation), ``degraded`` (partial/solo-retried
    generation, ``reason`` says why), or failed (``ok=False`` with a
    typed ``reason`` — never an unhandled exception).
    """

    uid: int
    tokens: np.ndarray
    ok: bool = True
    degraded: bool = False
    reason: str = ""
    attempts: int = 1


@dataclasses.dataclass
class EngineConfig:
    max_batch: int = 8
    max_seq: int = 512
    temperature: float = 0.0      # 0 = greedy
    eos_id: int = -1              # -1 = never stop on token
    seed: int = 0
    # --- resilience ---
    max_queue: int = 256          # bounded admission; 0 = unbounded
    request_timeout_s: Optional[float] = None
    retry: RetryPolicy = dataclasses.field(
        default_factory=lambda: RetryPolicy(max_attempts=3,
                                            base_delay_s=0.002,
                                            max_delay_s=0.05)
    )


class ServingEngine:
    def __init__(self, cfg, params, ecfg: EngineConfig):
        self.cfg = cfg
        self.params = params
        self.ecfg = ecfg
        self.queue: List[Request] = []
        self.done: Dict[int, np.ndarray] = {}
        self.results: Dict[int, RequestResult] = {}
        self._prefill = jax.jit(make_prefill(cfg, ecfg.max_seq))
        self._step = jax.jit(make_serve_step(cfg))
        self._rng = jax.random.PRNGKey(ecfg.seed)

    # ------------------------------------------------------------- submit
    def _validate_prompt(self, prompt: np.ndarray) -> np.ndarray:
        p = np.asarray(prompt)
        if p.ndim != 1 or len(p) == 0:
            raise ReproValidationError(
                f"prompt must be a non-empty 1-D token array; got shape "
                f"{p.shape}"
            )
        if len(p) > self.ecfg.max_seq:
            raise ReproValidationError(
                f"prompt length {len(p)} exceeds max_seq "
                f"{self.ecfg.max_seq}"
            )
        if not np.issubdtype(p.dtype, np.integer):
            if not np.all(np.isfinite(p)) or np.any(p != np.floor(p)):
                raise ReproValidationError(
                    "prompt tokens must be integers (got non-finite or "
                    "fractional values)"
                )
        vocab = getattr(self.cfg, "vocab", None)
        if np.any(p < 0) or (vocab is not None and np.any(p >= vocab)):
            raise ReproValidationError(
                f"prompt tokens outside [0, {vocab})"
            )
        return p.astype(np.int32)

    def submit(self, uid: int, prompt: np.ndarray, max_new: int = 32):
        """Enqueue a request. Raises ``ReproValidationError`` on malformed
        input and ``AdmissionError`` when the queue is full."""
        if max_new <= 0:
            raise ReproValidationError(f"max_new must be positive: {max_new}")
        p = self._validate_prompt(prompt)
        if self.ecfg.max_queue > 0 and len(self.queue) >= self.ecfg.max_queue:
            obs.counter("serve.rejected").inc()
            raise AdmissionError(
                "queue_full",
                f"admission queue full ({len(self.queue)}/"
                f"{self.ecfg.max_queue}); retry after run()",
            )
        obs.counter("serve.requests").inc()
        now = time.perf_counter()
        dl = (now + self.ecfg.request_timeout_s
              if self.ecfg.request_timeout_s else None)
        self.queue.append(
            Request(uid=uid, prompt=p, max_new=max_new, t_submit=now,
                    deadline=dl)
        )

    # ---------------------------------------------------------------- run
    def run(self) -> Dict[int, np.ndarray]:
        """Serve everything in the queue; returns uid -> generated tokens.

        Completion guarantee: every queued uid appears in the result (and
        in ``self.results`` with full status) — failed/expired requests
        map to an empty token array rather than raising.
        """
        buckets = defaultdict(list)
        for r in self.queue:
            buckets[len(r.prompt)].append(r)
        self.queue.clear()
        self.results = {}
        for _, reqs in sorted(buckets.items()):
            for i in range(0, len(reqs), self.ecfg.max_batch):
                self._serve_bucket(reqs[i : i + self.ecfg.max_batch])
        out, self.done = self.done, {}
        return out

    def run_detailed(self) -> Dict[int, RequestResult]:
        """Like ``run`` but returns the full per-request status map."""
        self.run()
        return self.results

    def _serve_bucket(self, reqs: List[Request]):
        """Retry-or-degrade wrapper: bucket retried whole, then failing
        requests re-run solo, and final stragglers are marked failed —
        this method never raises for per-request faults."""
        attempts = 1

        def bump(_a, _e, _d):
            nonlocal attempts
            attempts += 1

        try:
            gen = with_retry(
                lambda: self._run_bucket(reqs),
                policy=self.ecfg.retry,
                site="serve.bucket",
                on_retry=bump,
            )
            self._finish(reqs, gen, attempts=attempts,
                         degraded=attempts > 1,
                         reason="retried" if attempts > 1 else "")
            return
        except Exception as e:  # noqa: BLE001 — degrade path below
            obs.counter("serve.bucket_failed").inc()
            last = e
        if len(reqs) > 1:
            # degrade: the bucket keeps failing as a batch — serve each
            # request alone so one poisoned row cannot sink its neighbors
            for r in reqs:
                self._serve_bucket([r])
            for r in reqs:
                res = self.results[r.uid]
                if res.ok and not res.degraded:
                    res.degraded = True
                    res.reason = "bucket_degraded_to_solo"
            return
        r = reqs[0]
        obs.counter("serve.failed").inc()
        self.results[r.uid] = RequestResult(
            uid=r.uid, tokens=np.zeros(0, np.int32), ok=False,
            degraded=True, attempts=attempts,
            reason=f"{type(last).__name__}: {last}",
        )
        self.done[r.uid] = self.results[r.uid].tokens

    def _finish(self, reqs, gen, attempts=1, degraded=False, reason=""):
        for r_i, r in enumerate(reqs):
            toks = np.asarray(gen[r_i][: r.max_new], np.int32)
            timed_out = (r.deadline is not None
                         and len(toks) < r.max_new
                         and time.perf_counter() > r.deadline
                         and (self.ecfg.eos_id < 0
                              or self.ecfg.eos_id not in toks.tolist()))
            self.results[r.uid] = RequestResult(
                uid=r.uid, tokens=toks, ok=True,
                degraded=degraded or timed_out,
                attempts=attempts,
                reason="deadline_truncated" if timed_out else reason,
            )
            self.done[r.uid] = toks

    def _sample(self, logits) -> jnp.ndarray:
        if self.ecfg.temperature <= 0:
            return jnp.argmax(logits, axis=-1)
        self._rng, k = jax.random.split(self._rng)
        return jax.random.categorical(
            k, logits / self.ecfg.temperature, axis=-1
        )

    @staticmethod
    def _check_logits(logits):
        """Fault-site output validation: poisoned logits must not silently
        become argmax(NaN)=0 tokens."""
        host = np.asarray(logits)
        if not np.isfinite(host).all():
            raise NonFiniteOutputError("serve: non-finite logits")
        return host

    def _run_bucket(self, reqs: List[Request]) -> List[List[int]]:
        """One attempt at a bucket; pure w.r.t. engine state so retries
        can re-run it from scratch (results land via ``_finish``)."""
        B = len(reqs)
        t_start = time.perf_counter()
        qw = obs.histogram("serve.queue_wait_s")
        for r in reqs:
            if r.t_submit > 0:
                qw.observe(max(t_start - r.t_submit, 0.0))
        with obs.span("serve.bucket", batch=B, seq=len(reqs[0].prompt)):
            prompts = jnp.asarray(np.stack([r.prompt for r in reqs]))
            with obs.span("serve.prefill") as sp:
                faults.fault_point("serve.prefill")
                logits, state = self._prefill(self.params, prompts)
                logits = faults.poison("serve.prefill", logits)
                jax.block_until_ready(logits)
            obs.histogram("serve.prefill_s").observe(sp.duration_s)
            self._check_logits(logits[:, -1])
            max_new = max(r.max_new for r in reqs)
            tok = self._sample(logits[:, -1])[:, None]
            active = np.ones(B, bool)
            gen: List[List[int]] = [[] for _ in range(B)]
            for r_i in range(B):
                gen[r_i].append(int(tok[r_i, 0]))
            decode_h = obs.histogram("serve.decode_token_s")
            n_tok = B
            t_dec0 = time.perf_counter()
            for _ in range(max_new - 1):
                t0 = time.perf_counter()
                faults.fault_point("serve.decode")
                logits, state = self._step(self.params, state, tok)
                logits = faults.poison("serve.decode", logits)
                self._check_logits(logits[:, -1])
                tok = self._sample(logits[:, -1])[:, None]
                host = np.asarray(tok[:, 0])   # device sync
                decode_h.observe(time.perf_counter() - t0)
                now = time.perf_counter()
                for r_i in range(B):
                    if not active[r_i]:
                        continue
                    if len(gen[r_i]) >= reqs[r_i].max_new:
                        active[r_i] = False
                        continue
                    if (reqs[r_i].deadline is not None
                            and now > reqs[r_i].deadline):
                        # per-request timeout: stop generating for this
                        # row; _finish tags the partial result degraded
                        obs.counter("serve.deadline_truncated").inc()
                        active[r_i] = False
                        continue
                    t = int(host[r_i])
                    gen[r_i].append(t)
                    n_tok += 1
                    if t == self.ecfg.eos_id:
                        active[r_i] = False
                if not active.any():
                    break
            dt_dec = time.perf_counter() - t_dec0
            obs.counter("serve.tokens").inc(n_tok)
            if dt_dec > 0:
                obs.gauge("serve.tokens_per_s").set(n_tok / dt_dec)
        return gen


def cache_bytes(cfg, batch: int, seq: int) -> int:
    """KV-cache HBM footprint for reports/planning (bf16)."""
    if cfg.mixer == "attn" and cfg.mla:
        per_tok = cfg.kv_lora + cfg.qk_rope_dims
        return cfg.n_layers * batch * seq * per_tok * 2
    if cfg.mixer == "attn":
        per_tok = 2 * cfg.n_kv_heads * cfg.head_dim
        return cfg.n_layers * batch * seq * per_tok * 2
    state = 0
    if cfg.mixer == "mamba2":
        state = cfg.n_layers * batch * (
            cfg.n_ssm_heads * cfg.ssm_head_dim * cfg.ssm_state * 4
            + (cfg.ssm_conv - 1) * (cfg.d_inner_ssm + 2 * cfg.ssm_groups
                                    * cfg.ssm_state) * 2
        )
    if cfg.mixer == "rwkv6":
        H = cfg.d_model // 64
        state = cfg.n_layers * batch * (H * 64 * 64 * 4 + 2 * cfg.d_model * 2)
    if cfg.shared_attn_every > 0:
        state += (cfg.attn_sites * batch * seq
                  * 2 * cfg.n_kv_heads * cfg.head_dim * 2)
    return state
