"""Batched serving engine: bucketed prefill + masked decode.

Serving path used by examples/serve_lm.py and the decode dry-run cells:

  * ``make_serve_step(cfg)``   — the pure (params, state, token) -> (logits,
    state) decode function the dry-run lowers (one new token against a
    seq_len KV cache; the ``decode_*`` / ``long_*`` shape cells).
  * ``ServingEngine``          — groups queued requests into same-length
    buckets (no padding-token infidelity), prefills each bucket as a batch,
    then decodes with a per-row active mask, greedy or temperature sampling,
    EOS + max-token stopping. Finished rows idle until the bucket drains
    (continuous batching slot-swap is a documented extension point — it
    needs per-row cache indices, see DESIGN.md).
"""
from __future__ import annotations

import dataclasses
import time
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.models import model as model_lib
from repro.models.model import DecodeState


def make_serve_step(cfg):
    """One-token decode step (jit/pjit target for the dry-run)."""

    def serve_step(params, state: DecodeState, token):
        return model_lib.decode_step(cfg, params, token, state)

    return serve_step


def make_prefill(cfg, max_seq: int):
    def prefill_fn(params, tokens, **kw):
        return model_lib.prefill(cfg, params, tokens, max_seq=max_seq, **kw)

    return prefill_fn


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # (S,) int32
    max_new: int = 32
    out: Optional[np.ndarray] = None
    t_submit: float = 0.0         # perf_counter at submit(); queue-wait base


@dataclasses.dataclass
class EngineConfig:
    max_batch: int = 8
    max_seq: int = 512
    temperature: float = 0.0      # 0 = greedy
    eos_id: int = -1              # -1 = never stop on token
    seed: int = 0


class ServingEngine:
    def __init__(self, cfg, params, ecfg: EngineConfig):
        self.cfg = cfg
        self.params = params
        self.ecfg = ecfg
        self.queue: List[Request] = []
        self.done: Dict[int, np.ndarray] = {}
        self._prefill = jax.jit(make_prefill(cfg, ecfg.max_seq))
        self._step = jax.jit(make_serve_step(cfg))
        self._rng = jax.random.PRNGKey(ecfg.seed)

    def submit(self, uid: int, prompt: np.ndarray, max_new: int = 32):
        obs.counter("serve.requests").inc()
        self.queue.append(
            Request(uid=uid, prompt=np.asarray(prompt, np.int32),
                    max_new=max_new, t_submit=time.perf_counter())
        )

    # ---------------------------------------------------------------- run
    def run(self) -> Dict[int, np.ndarray]:
        """Serve everything in the queue; returns uid -> generated tokens."""
        buckets = defaultdict(list)
        for r in self.queue:
            buckets[len(r.prompt)].append(r)
        self.queue.clear()
        for _, reqs in sorted(buckets.items()):
            for i in range(0, len(reqs), self.ecfg.max_batch):
                self._run_bucket(reqs[i : i + self.ecfg.max_batch])
        out, self.done = self.done, {}
        return out

    def _sample(self, logits) -> jnp.ndarray:
        if self.ecfg.temperature <= 0:
            return jnp.argmax(logits, axis=-1)
        self._rng, k = jax.random.split(self._rng)
        return jax.random.categorical(
            k, logits / self.ecfg.temperature, axis=-1
        )

    def _run_bucket(self, reqs: List[Request]):
        B = len(reqs)
        t_start = time.perf_counter()
        qw = obs.histogram("serve.queue_wait_s")
        for r in reqs:
            if r.t_submit > 0:
                qw.observe(max(t_start - r.t_submit, 0.0))
        with obs.span("serve.bucket", batch=B, seq=len(reqs[0].prompt)):
            prompts = jnp.asarray(np.stack([r.prompt for r in reqs]))
            with obs.span("serve.prefill") as sp:
                logits, state = self._prefill(self.params, prompts)
                jax.block_until_ready(logits)
            obs.histogram("serve.prefill_s").observe(sp.duration_s)
            max_new = max(r.max_new for r in reqs)
            tok = self._sample(logits[:, -1])[:, None]
            active = np.ones(B, bool)
            gen = [[] for _ in range(B)]
            for r_i in range(B):
                gen[r_i].append(int(tok[r_i, 0]))
            decode_h = obs.histogram("serve.decode_token_s")
            n_tok = B
            t_dec0 = time.perf_counter()
            for _ in range(max_new - 1):
                t0 = time.perf_counter()
                logits, state = self._step(self.params, state, tok)
                tok = self._sample(logits[:, -1])[:, None]
                host = np.asarray(tok[:, 0])   # device sync
                decode_h.observe(time.perf_counter() - t0)
                for r_i in range(B):
                    if not active[r_i]:
                        continue
                    if len(gen[r_i]) >= reqs[r_i].max_new:
                        active[r_i] = False
                        continue
                    t = int(host[r_i])
                    gen[r_i].append(t)
                    n_tok += 1
                    if t == self.ecfg.eos_id:
                        active[r_i] = False
                if not active.any():
                    break
            dt_dec = time.perf_counter() - t_dec0
            obs.counter("serve.tokens").inc(n_tok)
            if dt_dec > 0:
                obs.gauge("serve.tokens_per_s").set(n_tok / dt_dec)
        for r_i, r in enumerate(reqs):
            self.done[r.uid] = np.asarray(gen[r_i][: r.max_new], np.int32)


def cache_bytes(cfg, batch: int, seq: int) -> int:
    """KV-cache HBM footprint for reports/planning (bf16)."""
    if cfg.mixer == "attn" and cfg.mla:
        per_tok = cfg.kv_lora + cfg.qk_rope_dims
        return cfg.n_layers * batch * seq * per_tok * 2
    if cfg.mixer == "attn":
        per_tok = 2 * cfg.n_kv_heads * cfg.head_dim
        return cfg.n_layers * batch * seq * per_tok * 2
    state = 0
    if cfg.mixer == "mamba2":
        state = cfg.n_layers * batch * (
            cfg.n_ssm_heads * cfg.ssm_head_dim * cfg.ssm_state * 4
            + (cfg.ssm_conv - 1) * (cfg.d_inner_ssm + 2 * cfg.ssm_groups
                                    * cfg.ssm_state) * 2
        )
    if cfg.mixer == "rwkv6":
        H = cfg.d_model // 64
        state = cfg.n_layers * batch * (H * 64 * 64 * 4 + 2 * cfg.d_model * 2)
    if cfg.shared_attn_every > 0:
        state += (cfg.attn_sites * batch * seq
                  * 2 * cfg.n_kv_heads * cfg.head_dim * 2)
    return state
