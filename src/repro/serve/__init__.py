"""Serving substrate: batched engine + decode-step factories."""
from .engine import (
    ServingEngine, EngineConfig, Request, RequestResult,
    make_serve_step, make_prefill, cache_bytes,
)
