"""Parameter / activation / cache sharding rules.

Mesh axes: ("pod", "data", "model") multi-pod or ("data", "model") single pod.
  * pod    — DCN: pure data parallelism (gradient all-reduce across pods)
  * data   — ICI: batch sharding + FSDP (ZeRO-3) parameter sharding
  * model  — ICI: tensor parallelism (Megatron col/row), expert parallelism,
             and KV-cache sequence sharding for decode (flash-decoding style)

Rules are path-based over the plain-dict param trees in models/. A leaf whose
rank is one above its rule gets a leading ``None`` (the stacked-layer axis).
Any axis whose size does not divide the dimension falls back to ``None`` —
sharding must never change numerics, only placement.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

FSDP = "data"
TP = "model"

COL = (FSDP, TP)      # (d_in, d_out) column parallel
ROW = (TP, FSDP)      # row parallel
REP2 = (None, None)

# ordered (path-suffix, base-spec) rules; first match wins
# NOTE embed/head: vocab over TP only — FSDP-sharding the embed dim makes
# the logits matmul contract over a data-sharded axis, which GSPMD resolves
# by all-reducing the full (B, S, V/TP) logits over "data" and replicating
# the batch through the entire backward pass (measured: 401 GiB/dev of
# collective traffic on smollm-360m train_4k; §Perf iteration 1).
_RULES = [
    (("embed", "tok"), (TP, None)),          # vocab x embed
    (("head",), (None, TP)),                 # embed x vocab
    # rwkv channel-mix: wk (D,F) col, wv (F,D) row, wr (D,D) col
    (("cmix", "wv"), ROW),
    # MoE: experts over TP (expert parallelism), d_model over FSDP
    (("moe", "router"), (FSDP, None)),
    (("moe", "wg"), (TP, FSDP, None)),
    (("moe", "wu"), (TP, FSDP, None)),
    (("moe", "wo"), (TP, None, FSDP)),
    # MLA up-projections: latent x (H*dh) — heads over TP
    (("w_uk",), (None, TP)),
    (("w_uv",), (None, TP)),
    (("w_dkv",), (FSDP, None)),
    (("w_krope",), (FSDP, None)),
    # SSM
    (("in_proj",), COL),
    (("out_proj",), ROW),
    (("conv_w",), (None, None)),
    (("conv_b",), (None,)),
    (("A_log",), (TP,)),
    (("ssm", "D"), (TP,)),
    (("dt_bias",), (TP,)),
    (("ssm", "norm"), (TP,)),
    # rwkv time-mix head params
    (("u",), (TP, None)),
    # generic projections
    (("wq",), COL), (("wk",), COL), (("wv",), COL),
    (("wg",), COL), (("wu",), COL), (("wi",), COL),
    (("wr",), COL),
    (("wo",), ROW),
]


def _match(path: Tuple[str, ...], rule: Tuple[str, ...]) -> bool:
    return len(path) >= len(rule) and tuple(path[-len(rule):]) == rule


def _divisible(spec, shape, mesh: Mesh):
    """Drop axes that don't divide their dimension (or exceed rank)."""
    out = []
    for i, ax in enumerate(spec):
        if ax is None or i >= len(shape):
            out.append(None)
            continue
        size = np.prod([mesh.shape[a] for a in (
            ax if isinstance(ax, tuple) else (ax,))])
        out.append(ax if shape[i] % size == 0 else None)
    return P(*out)


def _path_names(path) -> Tuple[str, ...]:
    names = []
    for k in path:
        if hasattr(k, "key"):
            names.append(str(k.key))
        elif hasattr(k, "name"):
            names.append(str(k.name))
        else:
            names.append(str(k))
    return tuple(names)


def fsdp_only_param_specs(params, mesh: Mesh):
    """FSDP-only (ZeRO-3) parameter sharding: no tensor parallelism.

    For small models the per-layer TP activation all-reduce tax exceeds the
    cost of gathering the (small) parameters themselves — §Perf iteration 4.
    Each leaf is sharded on its largest dimension divisible by the full
    (data × model) axis set, falling back to "data" only, then replicated.
    """
    axes_full = tuple(a for a in ("data", "model") if a in mesh.axis_names)
    size_full = int(np.prod([mesh.shape[a] for a in axes_full]))
    size_data = mesh.shape.get("data", 1)

    def leaf(arr):
        if arr.ndim == 0:
            return P()
        order = sorted(range(arr.ndim), key=lambda i: -arr.shape[i])
        for i in order:
            if arr.shape[i] % size_full == 0:
                spec = [None] * arr.ndim
                spec[i] = axes_full
                return P(*spec)
        for i in order:
            if "data" in mesh.axis_names and arr.shape[i] % size_data == 0:
                spec = [None] * arr.ndim
                spec[i] = "data"
                return P(*spec)
        return P()

    return jax.tree.map(leaf, params)


def param_specs(params, mesh: Mesh, fsdp: bool = True):
    """PartitionSpec tree matching the param tree."""
    have_fsdp = fsdp and FSDP in mesh.axis_names

    def leaf(path, arr):
        names = _path_names(path)
        base = None
        for rule, spec in _RULES:
            if _match(names, rule):
                base = spec
                break
        if base is None:
            return P()                                     # replicated
        if not have_fsdp:
            base = tuple(None if a == FSDP else a for a in base)
        if TP not in mesh.axis_names:
            base = tuple(None if a == TP else a for a in base)
        # stacked-layer leading axis
        if arr.ndim == len(base) + 1:
            base = (None,) + base
        elif arr.ndim != len(base):
            return P()
        return _divisible(base, arr.shape, mesh)

    return jax.tree_util.tree_map_with_path(leaf, params)


def batch_axes(mesh: Mesh):
    """Mesh axes used to shard the global batch."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def data_specs(batch: dict, mesh: Mesh, include_model: bool = False):
    """Shardings for a training batch: leading dim over (pod, data[, model]).

    Tries the longest axis tuple first, then progressively shorter ones —
    the batch is never silently replicated just because one extra axis
    doesn't divide it.
    """
    bd = batch_axes(mesh)
    candidates = []
    if include_model and TP in mesh.axis_names:
        candidates.append(bd + (TP,))
    candidates.append(bd)
    while len(candidates[-1]) > 1:
        candidates.append(candidates[-1][:-1])

    def leaf(arr):
        spec = [None] * arr.ndim
        for axes in candidates:
            size = np.prod([mesh.shape[a] for a in axes])
            if arr.ndim and arr.shape[0] % size == 0:
                spec[0] = axes
                break
        return P(*spec)

    return jax.tree.map(leaf, batch)


def decode_state_specs(cfg, state, mesh: Mesh):
    """Shardings for DecodeState: batch over (pod,data) when divisible,
    cache sequence over "model" (+ leftovers of (pod,data) when batch can't
    use them — the flash-decoding layout for long-context decode)."""
    bd = batch_axes(mesh)
    bd_size = int(np.prod([mesh.shape[a] for a in bd]))
    tp = TP if TP in mesh.axis_names else None

    def leaf(path, arr):
        names = _path_names(path)
        if arr.ndim == 0:
            return P()
        spec = [None] * arr.ndim
        # layout conventions: stacked caches lead with L (or n_sites);
        # batch is dim 1; seq (attention caches) is dim 2.
        if "cross" in names and arr.ndim == 3:  # enc_out (B, S_enc, D)
            if arr.shape[0] % bd_size == 0:
                spec[0] = bd
            return P(*spec)
        if arr.ndim >= 2:
            if arr.shape[1] % bd_size == 0:
                spec[1] = bd
                seq_axes = (tp,)
            else:
                seq_axes = tuple(a for a in (bd + ((tp,) if tp else ()))
                                 if a is not None) or (None,)
            is_seq_cache = any(n in names for n in ("k", "v", "c_kv",
                                                    "k_rope"))
            if is_seq_cache and arr.ndim >= 3:
                ax = seq_axes if len(seq_axes) > 1 else seq_axes[0]
                if ax is not None:
                    size = int(np.prod([mesh.shape[a] for a in (
                        ax if isinstance(ax, tuple) else (ax,))]))
                    if arr.shape[2] % size == 0:
                        spec[2] = ax
        return P(*spec)

    return jax.tree_util.tree_map_with_path(leaf, state)


def make_sharding(tree_specs, mesh: Mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree_specs,
        is_leaf=lambda x: isinstance(x, P),
    )


# ------------------------------------------------------------ hint context
# Model code is mesh-agnostic; distribution-sensitive spots (decode
# attention) ask for placement hints through this context. Without an
# active mesh the hints are no-ops, so single-device paths are untouched.
import contextlib
import contextvars

_HINT_MESH: contextvars.ContextVar = contextvars.ContextVar(
    "repro_hint_mesh", default=None
)


@contextlib.contextmanager
def hint_mesh(mesh: Mesh):
    tok = _HINT_MESH.set(mesh)
    try:
        yield
    finally:
        _HINT_MESH.reset(tok)


def hint(x, *axes):
    """with_sharding_constraint(x, P(*axes)) under an active hint mesh.

    ``axes`` entries: None | "batch" (-> (pod, data) as divisible) |
    "seq" (-> "model", plus any batch axes the batch dim could not use —
    matching decode_state_specs' cache layout for batch=1 long-context) |
    "model" | explicit axis name. Axes that don't divide are dropped.
    """
    mesh = _HINT_MESH.get()
    if mesh is None:
        return x
    spec = []
    batch_used = True
    for i, a in enumerate(axes):
        if a is None:
            spec.append(None)
            continue
        if a == "batch":
            bd = batch_axes(mesh)
            size = int(np.prod([mesh.shape[ax] for ax in bd]))
            ok = bd and x.shape[i] % size == 0
            batch_used = bool(ok)
            spec.append(bd if ok else None)
            continue
        if a == "seq":
            cands = []
            if not batch_used:
                cands.append(batch_axes(mesh) + ((TP,) if TP in
                                                 mesh.axis_names else ()))
            if TP in mesh.axis_names:
                cands.append((TP,))
            chosen = None
            for cand in cands:
                cand = tuple(c for c in cand if c)
                size = int(np.prod([mesh.shape[ax] for ax in cand]))
                if cand and x.shape[i] % size == 0:
                    chosen = cand if len(cand) > 1 else cand[0]
                    break
            spec.append(chosen)
            continue
        if a in mesh.axis_names and x.shape[i] % mesh.shape[a] == 0:
            spec.append(a)
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec))
    )
