"""Load-aware placement — the SPMD incarnation of PB-SYM-PD-SCHED.

The paper shortens the critical path by coloring heavy subdomains first so
the OpenMP scheduler starts them early. An SPMD mesh has no dynamic
scheduler: the equivalent freedom is *which device owns which work*. LPT
(Longest Processing Time first) greedy assignment of tile loads to devices
minimizes makespan the same way the paper's heaviest-first coloring does —
Graham's bound applies to both.

Also used for MoE expert-load analysis (DESIGN.md §5 crossover).
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class Assignment:
    device_of_tile: np.ndarray   # (ntiles,) int
    tiles_of_device: list        # P lists of tile ids
    makespan: float
    total: float

    @property
    def imbalance(self) -> float:
        """makespan / perfect-balance ratio (1.0 = perfect)."""
        P = len(self.tiles_of_device)
        ideal = self.total / P if P else 0.0
        return self.makespan / ideal if ideal > 0 else 1.0


def lpt_assign(loads: np.ndarray, P: int) -> Assignment:
    """Greedy LPT: heaviest tile to least-loaded device."""
    loads = np.asarray(loads, dtype=np.float64).reshape(-1)
    order = np.argsort(-loads, kind="stable")
    heap = [(0.0, p) for p in range(P)]
    heapq.heapify(heap)
    device_of = np.zeros(loads.size, dtype=np.int64)
    tiles_of = [[] for _ in range(P)]
    for t in order:
        w, p = heapq.heappop(heap)
        device_of[t] = p
        tiles_of[p].append(int(t))
        heapq.heappush(heap, (w + loads[t], p))
    per_dev = np.zeros(P)
    np.add.at(per_dev, device_of, loads)
    return Assignment(
        device_of_tile=device_of,
        tiles_of_device=tiles_of,
        makespan=float(per_dev.max()) if P else 0.0,
        total=float(loads.sum()),
    )


def block_assign(ntiles: Tuple[int, int, int], P: int) -> Assignment:
    """Naive contiguous-block assignment (the unscheduled baseline)."""
    n = int(np.prod(ntiles))
    device_of = (np.arange(n) * P) // n
    tiles_of = [list(np.where(device_of == p)[0]) for p in range(P)]
    return Assignment(device_of, tiles_of, float("nan"), float("nan"))


def imbalance_stats(loads: np.ndarray, P: int) -> dict:
    """Compare naive block split vs LPT for reporting/benchmarks."""
    loads = np.asarray(loads, dtype=np.float64).reshape(-1)
    total = loads.sum()
    ideal = total / P
    # block split
    n = loads.size
    dev = (np.arange(n) * P) // n
    per_block = np.zeros(P)
    np.add.at(per_block, dev, loads)
    a = lpt_assign(loads, P)
    return {
        "ideal": ideal,
        "block_makespan": float(per_block.max()),
        "lpt_makespan": a.makespan,
        "block_imbalance": float(per_block.max() / ideal) if ideal else 1.0,
        "lpt_imbalance": a.imbalance,
    }


def split_counts_round_robin(counts: np.ndarray, R: int) -> np.ndarray:
    """Split per-bucket point counts as evenly as possible over R replicas.

    Returns (R, *counts.shape): replica r gets ceil/floor shares such that
    the sum over r equals the original counts (used by the hybrid/REP
    strategy to deal a bucket's points across the replica mesh axis).
    """
    counts = np.asarray(counts)
    base = counts // R
    rem = counts % R
    out = np.broadcast_to(base, (R,) + counts.shape).copy()
    r_idx = np.arange(R).reshape((R,) + (1,) * counts.ndim)
    out += (r_idx < rem).astype(counts.dtype)
    return out
