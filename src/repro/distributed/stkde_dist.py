"""Multi-device STKDE strategies (shard_map) — the paper's §4/§5 on a TPU mesh.

Strategy map (see DESIGN.md §2 for the full paper→TPU table):

  stkde_dr      PB-SYM-DR   points sharded over all devices, per-device full
                            grid, all-reduce. Pleasingly parallel; comm = grid.
  stkde_dd      PB-SYM-DD   grid block-sharded over a 2-D device grid; points
                            overlap-bucketed (cut-cylinder work overhead);
                            ZERO communication.
  stkde_pd      PB-SYM-PD   work-efficient owner-computes: points home-
                            bucketed, each device computes a halo-extended
                            local grid, halos folded into neighbors with
                            ppermute (races -> halo exchange).
  stkde_dd_lpt  PB-SYM-PD-SCHED   fine tiles, LPT load-aware placement
                            (scheduling -> placement), tile-soup assembly.
  stkde_hybrid  PB-SYM-PD-REP     mesh factored (rep × workers): each
                            bucket's points dealt over the rep axis, PD per
                            slice, psum over rep only. r=1 ⇒ PD, r=P ⇒ DR.

All strategies are normalization-consistent with ``core.pb`` (global n) and
are cross-tested for exact agreement in tests/test_stkde_distributed.py.
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map, pcast

from repro.core.geometry import Domain
from repro.core import bucketing, kernels_math as km
from repro.core.pb import pb as _pb
from repro.obs import trace as obs_trace
from repro.resilience import faults as _faults
from . import partition

PARK = -1e8  # parked coordinate for invalid/padded points


def _pad_tile_grid(points, valid, A, B):
    """Pad bucket arrays to the full (A, B) device grid.

    ceil(G/A)*A can overshoot G, leaving fewer tiles than devices — the
    missing (edge) tiles are empty by construction."""
    na, nb = points.shape[:2]
    if na == A and nb == B:
        return points, valid
    pp = np.zeros((A, B) + points.shape[2:], points.dtype)
    vv = np.zeros((A, B) + valid.shape[2:], valid.dtype)
    pp[:na, :nb] = points
    vv[:na, :nb] = valid
    pp[vv == 0] = PARK
    return pp, vv


def _mesh_sizes(mesh: Mesh, axes) -> Tuple[int, ...]:
    return tuple(mesh.shape[a] for a in axes)


def _park_invalid(pts, valid):
    """Move invalid bucket slots far outside every domain."""
    return jnp.where(valid[..., None] > 0, pts, PARK)


# ------------------------------------------------------------------ DR
def prepare_dr(
    points: np.ndarray, dom: Domain, mesh: Mesh, axes
) -> jnp.ndarray:
    """Pad points to a multiple of the device count (PARK fills)."""
    pts = np.asarray(points, dtype=np.float32)
    n = len(pts)
    Ptot = int(np.prod(_mesh_sizes(mesh, axes)))
    npad = bucketing.round_up(max(n, Ptot), Ptot)
    full = np.full((npad, 3), PARK, dtype=np.float32)
    full[:n] = pts
    return jnp.asarray(full)


def stkde_dr(
    points: np.ndarray,
    dom: Domain,
    mesh: Mesh,
    axes: Tuple[str, ...] = ("data", "model"),
    ks: km.SpatialKernel = km.DEFAULT_KS,
    kt: km.TemporalKernel = km.DEFAULT_KT,
    n_total: Optional[int] = None,
) -> jnp.ndarray:
    """Domain replication: shard points, replicate grid, all-reduce.

    ``n_total`` overrides the normalization count — chunked execution
    passes the *global* point count while feeding a chunk at a time.
    """
    n = int(n_total) if n_total is not None else len(points)
    with obs_trace.span("stkde.dr", n=n, mesh=str(dict(mesh.shape))):
        with obs_trace.span("stkde.dr.prepare"):
            full = prepare_dr(points, dom, mesh, axes)
            fn = build_dr(dom, mesh, axes, n, ks, kt)
        with obs_trace.span("stkde.dr.execute", blocking=False):
            return fn(full)


def build_dr(dom: Domain, mesh: Mesh, axes, n: int,
             ks=km.DEFAULT_KS, kt=km.DEFAULT_KT, collectives: bool = True):
    """Jitted DR computation over pre-sharded points (dry-run lowerable).

    ``collectives=False`` compiles the same per-device point work but skips
    the all-reduce, returning the device-stacked partial grids — the
    reconciliation probe for the planner's ``comm_s`` term.
    """

    def f(local):  # (npad/P, 3)
        g = _pb(local, dom, variant="sym", ks=ks, kt=kt, n_total=n)
        if collectives:
            return jax.lax.psum(g, axes)
        return g[None]

    out_specs = (
        P(None, None, None) if collectives else P(axes, None, None, None)
    )
    return jax.jit(shard_map(
        f, mesh=mesh, in_specs=P(axes), out_specs=out_specs
    ))


# ------------------------------------------------------------------ DD
def _device_grid_dims(dom: Domain, A: int, B: int) -> Tuple[int, int]:
    return (math.ceil(dom.Gx / A), math.ceil(dom.Gy / B))


def _local_domain(dom: Domain, gx_loc: int, gy_loc: int,
                  halo: int = 0) -> Domain:
    """A device-local domain at canonical origin (points are shifted)."""
    import dataclasses

    return dataclasses.replace(
        dom,
        gx=(gx_loc + 2 * halo) * dom.sres,
        gy=(gy_loc + 2 * halo) * dom.sres,
        gt=dom.Gt * dom.tres,
    )


def prepare_dd(
    points: np.ndarray, dom: Domain, mesh: Mesh, axes,
    cap: Optional[int] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Overlap-bucket points onto the (A, B) device grid (DD layout)."""
    A, B = _mesh_sizes(mesh, axes)
    pts = np.asarray(points, dtype=np.float32)
    gx_loc, gy_loc = _device_grid_dims(dom, A, B)
    b = bucketing.bucket_points_overlap(
        pts, dom, (gx_loc, gy_loc, dom.Gt), cap=cap
    )
    na, nb = b.ntiles[0], b.ntiles[1]
    bpts, bval = _pad_tile_grid(
        b.points.reshape(na, nb, b.cap, 3),
        b.valid.reshape(na, nb, b.cap).astype(np.float32), A, B)
    return jnp.asarray(bpts), jnp.asarray(bval)


def stkde_dd(
    points: np.ndarray,
    dom: Domain,
    mesh: Mesh,
    axes: Tuple[str, str] = ("data", "model"),
    cap: Optional[int] = None,
    ks: km.SpatialKernel = km.DEFAULT_KS,
    kt: km.TemporalKernel = km.DEFAULT_KT,
    n_total: Optional[int] = None,
) -> jnp.ndarray:
    """Domain decomposition: block-sharded grid, overlap-routed points."""
    A, B = _mesh_sizes(mesh, axes)
    n = int(n_total) if n_total is not None else len(points)
    gx_loc, gy_loc = _device_grid_dims(dom, A, B)
    with obs_trace.span("stkde.dd", n=n, mesh=str(dict(mesh.shape))):
        with obs_trace.span("stkde.dd.bucket"):
            bpts, bval = prepare_dd(points, dom, mesh, axes, cap=cap)
        fn = build_dd(dom, mesh, axes, n, ks, kt)
        with obs_trace.span("stkde.dd.execute", blocking=False):
            out = fn(bpts, bval)
            out = out.reshape(A, B, gx_loc, gy_loc, dom.Gt)
            out = out.transpose(0, 2, 1, 3, 4).reshape(
                A * gx_loc, B * gy_loc, dom.Gt)
            return out[: dom.Gx, : dom.Gy, :]


def build_dd(dom: Domain, mesh: Mesh, axes, n: int,
             ks=km.DEFAULT_KS, kt=km.DEFAULT_KT):
    """Jitted DD over overlap-bucketed points (dry-run lowerable)."""
    ax, ay = axes
    A, B = _mesh_sizes(mesh, axes)
    gx_loc, gy_loc = _device_grid_dims(dom, A, B)
    ldom = _local_domain(dom, gx_loc, gy_loc)

    def f(pts_blk, val_blk):  # (1, 1, cap, 3), (1, 1, cap)
        i = jax.lax.axis_index(ax).astype(jnp.float32)
        j = jax.lax.axis_index(ay).astype(jnp.float32)
        p = _park_invalid(pts_blk[0, 0], val_blk[0, 0])
        shift = jnp.stack(
            [i * gx_loc * dom.sres, j * gy_loc * dom.sres, jnp.float32(0.0)]
        )
        g = _pb(p - shift, ldom, variant="sym", ks=ks, kt=kt, n_total=n)
        return g[None, None]  # (1, 1, gx_loc, gy_loc, Gt)

    return jax.jit(shard_map(
        f,
        mesh=mesh,
        in_specs=(P(ax, ay, None, None), P(ax, ay, None)),
        out_specs=P(ax, ay, None, None, None),
    ))


# ------------------------------------------------------------------ PD
def prepare_pd(
    points: np.ndarray, dom: Domain, mesh: Mesh, axes,
    cap: Optional[int] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Home-bucket points onto the (A, B) device grid (PD layout)."""
    A, B = _mesh_sizes(mesh, axes)
    pts = np.asarray(points, dtype=np.float32)
    gx_loc, gy_loc = _device_grid_dims(dom, A, B)
    b = bucketing.bucket_points_home(
        pts, dom, (gx_loc, gy_loc, dom.Gt), cap=cap
    )
    na, nb = b.ntiles[0], b.ntiles[1]
    bp, bv = _pad_tile_grid(
        b.points.reshape(na, nb, b.cap, 3),
        b.valid.reshape(na, nb, b.cap).astype(np.float32), A, B)
    return jnp.asarray(bp), jnp.asarray(bv)


def stkde_pd(
    points: np.ndarray,
    dom: Domain,
    mesh: Mesh,
    axes: Tuple[str, str] = ("data", "model"),
    cap: Optional[int] = None,
    ks: km.SpatialKernel = km.DEFAULT_KS,
    kt: km.TemporalKernel = km.DEFAULT_KT,
    n_total: Optional[int] = None,
    _rep_axis: Optional[str] = None,
    _pts_override=None,
) -> jnp.ndarray:
    """Work-efficient owner-computes + halo exchange (PB-SYM-PD)."""
    ax, ay = axes
    A, B = _mesh_sizes(mesh, axes)
    pts = np.asarray(points, dtype=np.float32)
    n = int(n_total) if n_total is not None else len(pts)
    gx_loc, gy_loc = _device_grid_dims(dom, A, B)
    Hs = dom.Hs
    if gx_loc < Hs or gy_loc < Hs:
        raise ValueError(
            f"PD requires subdomains >= bandwidth: local ({gx_loc},{gy_loc})"
            f" vs Hs={Hs}; use DD/DR or a coarser device grid"
            " (paper §5.1 constraint)"
        )
    strat = "pd" if _rep_axis is None else "hybrid"
    with obs_trace.span(f"stkde.{strat}", n=n, mesh=str(dict(mesh.shape))):
        if _pts_override is None:
            with obs_trace.span(f"stkde.{strat}.bucket"):
                bpts, bval = prepare_pd(pts, dom, mesh, axes, cap=cap)
        else:  # hybrid path: (R, A, B, cap, 3) sharded over rep too
            bpts, bval = _pts_override
        # fault site dist.halo: an injected OOM here models a failed
        # strategy build (halo buffers are the PD-only allocation); the
        # api-level fallback then reroutes the query to the dr baseline.
        _faults.fault_point("dist.halo")
        fn = build_pd(dom, mesh, axes, n, ks, kt, rep_axis=_rep_axis)
        with obs_trace.span(f"stkde.{strat}.execute", blocking=False):
            out = fn(bpts, bval)
            out = out.reshape(A, B, gx_loc, gy_loc, dom.Gt)
            out = out.transpose(0, 2, 1, 3, 4).reshape(
                A * gx_loc, B * gy_loc, dom.Gt)
            # nan-kind injection poisons the folded halos; callers
            # validate via resilience.degrade.ensure_finite
            return _faults.poison(
                "dist.halo", out[: dom.Gx, : dom.Gy, :])


def build_pd(dom: Domain, mesh: Mesh, axes, n: int,
             ks=km.DEFAULT_KS, kt=km.DEFAULT_KT, rep_axis=None,
             collectives: bool = True):
    """Jitted PD (owner-computes + halo exchange) over home-bucketed points.

    Input layout: (A, B, cap, 3) — or (R, A, B, cap, 3) with rep_axis for
    the hybrid/REP strategy. Dry-run lowerable with ShapeDtypeStructs.
    ``collectives=False`` skips the halo ppermute folds (and rep psum) —
    the reconciliation probe for the planner's ``comm_s`` term; the output
    is then the unfolded interior (numerically incomplete by design).
    """
    ax, ay = axes
    A, B = _mesh_sizes(mesh, axes)
    gx_loc, gy_loc = _device_grid_dims(dom, A, B)
    Hs = dom.Hs
    ldom = _local_domain(dom, gx_loc, gy_loc, halo=Hs)
    if rep_axis is None:
        in_specs = (P(ax, ay, None, None), P(ax, ay, None))
    else:
        in_specs = (
            P(rep_axis, ax, ay, None, None),
            P(rep_axis, ax, ay, None),
        )
    if rep_axis is not None and not collectives:
        # no rep-psum to make the output rep-invariant: return the
        # rep-stacked partial grids instead (reconciliation probe layout)
        out_specs = P(rep_axis, ax, ay, None, None, None)
    else:
        out_specs = P(ax, ay, None, None, None)

    def f(pts_blk, val_blk):
        i = jax.lax.axis_index(ax).astype(jnp.float32)
        j = jax.lax.axis_index(ay).astype(jnp.float32)
        p = _park_invalid(
            pts_blk.reshape(-1, 3), val_blk.reshape(-1)
        )
        shift = jnp.stack(
            [
                (i * gx_loc - Hs) * dom.sres,
                (j * gy_loc - Hs) * dom.sres,
                jnp.float32(0.0),
            ]
        )
        L = _pb(p - shift, ldom, variant="sym", ks=ks, kt=kt, n_total=n)
        if not collectives:
            out = L[Hs : Hs + gx_loc, Hs : Hs + gy_loc, :][None, None]
            return out if rep_axis is None else out[None]
        # ---- fold halos: X phase (full-y slabs), then Y phase (interior-x)
        fwd_x = [(k, k + 1) for k in range(A - 1)]
        bwd_x = [(k, k - 1) for k in range(1, A)]
        from_left = jax.lax.ppermute(L[-Hs:, :, :], ax, fwd_x)
        from_right = jax.lax.ppermute(L[:Hs, :, :], ax, bwd_x)
        L = L.at[Hs : 2 * Hs].add(from_left)
        L = L.at[gx_loc : gx_loc + Hs].add(from_right)

        fwd_y = [(k, k + 1) for k in range(B - 1)]
        bwd_y = [(k, k - 1) for k in range(1, B)]
        top = L[Hs : Hs + gx_loc, -Hs:, :]
        bot = L[Hs : Hs + gx_loc, :Hs, :]
        from_bot = jax.lax.ppermute(top, ay, fwd_y)
        from_top = jax.lax.ppermute(bot, ay, bwd_y)
        interior = L[Hs : Hs + gx_loc]
        interior = interior.at[:, Hs : 2 * Hs].add(from_bot)
        interior = interior.at[:, gy_loc : gy_loc + Hs].add(from_top)
        out = interior[:, Hs : Hs + gy_loc, :]
        if rep_axis is not None:
            out = jax.lax.psum(out, rep_axis)
        return out[None, None]

    return jax.jit(shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs))


def prepare_pd_xt(
    points: np.ndarray, dom: Domain, mesh: Mesh, axes,
    cap: Optional[int] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Home-bucket points onto the (A, B) = (x-tile, t-tile) device grid."""
    A, B = _mesh_sizes(mesh, axes)
    pts = np.asarray(points, dtype=np.float32)
    gx_loc = math.ceil(dom.Gx / A)
    gt_loc = math.ceil(dom.Gt / B)
    b = bucketing.bucket_points_home(
        pts, dom, (gx_loc, dom.Gy, gt_loc), cap=cap
    )
    na, nt = b.ntiles[0], b.ntiles[2]
    bp, bv = _pad_tile_grid(
        b.points.reshape(na, nt, b.cap, 3),
        b.valid.reshape(na, nt, b.cap).astype(np.float32), A, B)
    return jnp.asarray(bp), jnp.asarray(bv)


def build_pd_xt(dom: Domain, mesh: Mesh, axes, n: int,
                ks=km.DEFAULT_KS, kt=km.DEFAULT_KT, rep_axis=None,
                collectives: bool = True):
    """PD split over (X, T) instead of (X, Y) — §Perf STKDE iteration.

    The halo a subdomain exchanges is its boundary thickened by the
    bandwidth: splitting the *temporal* axis pays Ht-wide halos instead of
    Hs-wide ones. For long-duration instances (eBird: Gt=2435, Ht=5 vs
    Hs=30) this cuts halo traffic ~3x at identical work. Input layout:
    (A, B, cap, 3) buckets over (x-tile, t-tile).
    ``collectives=False`` skips the halo ppermute folds (and rep psum) —
    the reconciliation probe for the planner's ``comm_s`` term; the output
    is then the unfolded interior (numerically incomplete by design).
    """
    ax, at = axes
    A, B = _mesh_sizes(mesh, axes)
    gx_loc = math.ceil(dom.Gx / A)
    gt_loc = math.ceil(dom.Gt / B)
    Hs, Ht = dom.Hs, dom.Ht
    if gx_loc < Hs or gt_loc < Ht:
        raise ValueError("PD-XT requires subdomains >= bandwidth")
    import dataclasses

    ldom = dataclasses.replace(
        dom,
        gx=(gx_loc + 2 * Hs) * dom.sres,
        gy=dom.Gy * dom.sres,
        gt=(gt_loc + 2 * Ht) * dom.tres,
    )
    if rep_axis is None:
        in_specs = (P(ax, at, None, None), P(ax, at, None))
    else:
        in_specs = (P(rep_axis, ax, at, None, None),
                    P(rep_axis, ax, at, None))
    if rep_axis is not None and not collectives:
        out_specs = P(rep_axis, ax, at, None, None, None)
    else:
        out_specs = P(ax, at, None, None, None)

    def f(pts_blk, val_blk):
        i = jax.lax.axis_index(ax).astype(jnp.float32)
        j = jax.lax.axis_index(at).astype(jnp.float32)
        p = _park_invalid(pts_blk.reshape(-1, 3), val_blk.reshape(-1))
        shift = jnp.stack(
            [
                (i * gx_loc - Hs) * dom.sres,
                jnp.float32(0.0),
                (j * gt_loc - Ht) * dom.tres,
            ]
        )
        L = _pb(p - shift, ldom, variant="sym", ks=ks, kt=kt, n_total=n)
        if not collectives:
            out = L[Hs : Hs + gx_loc, :, Ht : Ht + gt_loc][None, None]
            return out if rep_axis is None else out[None]
        # fold halos: X phase (full-t slabs), then T phase (interior-x)
        fwd_x = [(k, k + 1) for k in range(A - 1)]
        bwd_x = [(k, k - 1) for k in range(1, A)]
        L = L.at[Hs : 2 * Hs].add(
            jax.lax.ppermute(L[-Hs:], ax, fwd_x))
        L = L.at[gx_loc : gx_loc + Hs].add(
            jax.lax.ppermute(L[:Hs], ax, bwd_x))
        fwd_t = [(k, k + 1) for k in range(B - 1)]
        bwd_t = [(k, k - 1) for k in range(1, B)]
        interior = L[Hs : Hs + gx_loc]
        interior = interior.at[:, :, Ht : 2 * Ht].add(
            jax.lax.ppermute(interior[:, :, -Ht:], at, fwd_t))
        interior = interior.at[:, :, gt_loc : gt_loc + Ht].add(
            jax.lax.ppermute(interior[:, :, :Ht], at, bwd_t))
        out = interior[:, :, Ht : Ht + gt_loc]
        if rep_axis is not None:
            out = jax.lax.psum(out, rep_axis)
        return out[None, None]

    return jax.jit(shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs))


def stkde_pd_xt(
    points: np.ndarray,
    dom: Domain,
    mesh: Mesh,
    axes: Tuple[str, str] = ("data", "model"),
    cap: Optional[int] = None,
    ks: km.SpatialKernel = km.DEFAULT_KS,
    kt: km.TemporalKernel = km.DEFAULT_KT,
    n_total: Optional[int] = None,
) -> jnp.ndarray:
    """PD with an (X, T) device grid (small temporal halos)."""
    ax, at = axes
    A, B = _mesh_sizes(mesh, axes)
    pts = np.asarray(points, dtype=np.float32)
    n = int(n_total) if n_total is not None else len(pts)
    gx_loc = math.ceil(dom.Gx / A)
    gt_loc = math.ceil(dom.Gt / B)
    bpts, bval = prepare_pd_xt(pts, dom, mesh, axes, cap=cap)
    fn = build_pd_xt(dom, mesh, axes, n, ks, kt)
    out = fn(bpts, bval)
    out = out.reshape(A, B, gx_loc, dom.Gy, gt_loc)
    out = out.transpose(0, 2, 3, 1, 4).reshape(
        A * gx_loc, dom.Gy, B * gt_loc)
    return out[: dom.Gx, :, : dom.Gt]


def prepare_pd_xyt(
    points: np.ndarray, dom: Domain, mesh: Mesh, axes,
    cap: Optional[int] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Home-bucket points onto the (A, B, C) = (x, y, t) device grid."""
    A, B, C = _mesh_sizes(mesh, axes)
    pts = np.asarray(points, dtype=np.float32)
    gx_loc = math.ceil(dom.Gx / A)
    gy_loc = math.ceil(dom.Gy / B)
    gt_loc = math.ceil(dom.Gt / C)
    b = bucketing.bucket_points_home(
        pts, dom, (gx_loc, gy_loc, gt_loc), cap=cap
    )
    na, nb, nt = b.ntiles
    pp = np.full((A, B, C, b.cap, 3), PARK, dtype=np.float32)
    vv = np.zeros((A, B, C, b.cap), dtype=np.float32)
    pp[:na, :nb, :nt] = b.points
    vv[:na, :nb, :nt] = b.valid.astype(np.float32)
    return jnp.asarray(pp), jnp.asarray(vv)


def build_pd_xyt(dom: Domain, mesh: Mesh, axes, n: int,
                 ks=km.DEFAULT_KS, kt=km.DEFAULT_KT,
                 collectives: bool = True):
    """Full 3-D PD decomposition (the paper's A×B×C) for multi-pod meshes.

    Splits (X, Y, T) over three mesh axes — e.g. pod×data×model = 2×16×16
    — with halo folds in all three directions (Hs, Hs, Ht wide). On the
    multi-pod mesh this keeps each subdomain 512× smaller than the grid
    while halo traffic stays proportional to subdomain surface; the
    cross-pod (DCN) direction is X, which exchanges only two
    Hs-thick slabs per build.
    ``collectives=False`` skips all three halo-fold phases — the
    reconciliation probe for the planner's ``comm_s`` term; the output is
    then the unfolded interior (numerically incomplete by design).
    """
    ax, ay, at = axes
    A, B, C = _mesh_sizes(mesh, axes)
    gx_loc = math.ceil(dom.Gx / A)
    gy_loc = math.ceil(dom.Gy / B)
    gt_loc = math.ceil(dom.Gt / C)
    Hs, Ht = dom.Hs, dom.Ht
    if gx_loc < Hs or gy_loc < Hs or gt_loc < Ht:
        raise ValueError("PD-XYT requires subdomains >= bandwidth")
    import dataclasses

    ldom = dataclasses.replace(
        dom,
        gx=(gx_loc + 2 * Hs) * dom.sres,
        gy=(gy_loc + 2 * Hs) * dom.sres,
        gt=(gt_loc + 2 * Ht) * dom.tres,
    )
    in_specs = (P(ax, ay, at, None, None), P(ax, ay, at, None))
    out_specs = P(ax, ay, at, None, None, None)

    def f(pts_blk, val_blk):
        i = jax.lax.axis_index(ax).astype(jnp.float32)
        j = jax.lax.axis_index(ay).astype(jnp.float32)
        k = jax.lax.axis_index(at).astype(jnp.float32)
        p = _park_invalid(pts_blk.reshape(-1, 3), val_blk.reshape(-1))
        shift = jnp.stack(
            [
                (i * gx_loc - Hs) * dom.sres,
                (j * gy_loc - Hs) * dom.sres,
                (k * gt_loc - Ht) * dom.tres,
            ]
        )
        L = _pb(p - shift, ldom, variant="sym", ks=ks, kt=kt, n_total=n)
        if not collectives:
            out = L[Hs : Hs + gx_loc, Hs : Hs + gy_loc, Ht : Ht + gt_loc]
            return out[None, None, None]
        # X phase (full-(y,t) slabs) -> Y phase (interior-x) -> T phase
        fwd = lambda nn: [(q, q + 1) for q in range(nn - 1)]
        bwd = lambda nn: [(q, q - 1) for q in range(1, nn)]
        L = L.at[Hs : 2 * Hs].add(jax.lax.ppermute(L[-Hs:], ax, fwd(A)))
        L = L.at[gx_loc : gx_loc + Hs].add(
            jax.lax.ppermute(L[:Hs], ax, bwd(A)))
        ix = L[Hs : Hs + gx_loc]
        ix = ix.at[:, Hs : 2 * Hs].add(
            jax.lax.ppermute(ix[:, -Hs:], ay, fwd(B)))
        ix = ix.at[:, gy_loc : gy_loc + Hs].add(
            jax.lax.ppermute(ix[:, :Hs], ay, bwd(B)))
        iy = ix[:, Hs : Hs + gy_loc]
        iy = iy.at[:, :, Ht : 2 * Ht].add(
            jax.lax.ppermute(iy[:, :, -Ht:], at, fwd(C)))
        iy = iy.at[:, :, gt_loc : gt_loc + Ht].add(
            jax.lax.ppermute(iy[:, :, :Ht], at, bwd(C)))
        out = iy[:, :, Ht : Ht + gt_loc]
        return out[None, None, None]

    return jax.jit(shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs))


def stkde_pd_xyt(
    points: np.ndarray,
    dom: Domain,
    mesh: Mesh,
    axes: Tuple[str, str, str] = ("pod", "data", "model"),
    cap: Optional[int] = None,
    ks: km.SpatialKernel = km.DEFAULT_KS,
    kt: km.TemporalKernel = km.DEFAULT_KT,
    n_total: Optional[int] = None,
) -> jnp.ndarray:
    """Paper-style 3-D decomposition across a three-axis (multi-pod) mesh."""
    A, B, C = _mesh_sizes(mesh, axes)
    pts = np.asarray(points, dtype=np.float32)
    n = int(n_total) if n_total is not None else len(pts)
    gx_loc = math.ceil(dom.Gx / A)
    gy_loc = math.ceil(dom.Gy / B)
    gt_loc = math.ceil(dom.Gt / C)
    bpts, bval = prepare_pd_xyt(pts, dom, mesh, axes, cap=cap)
    fn = build_pd_xyt(dom, mesh, axes, n, ks, kt)
    out = fn(bpts, bval)
    out = out.reshape(A, B, C, gx_loc, gy_loc, gt_loc)
    out = out.transpose(0, 3, 1, 4, 2, 5).reshape(
        A * gx_loc, B * gy_loc, C * gt_loc)
    return out[: dom.Gx, : dom.Gy, : dom.Gt]


# ------------------------------------------------------------------ hybrid
def prepare_hybrid(
    points: np.ndarray, dom: Domain, mesh: Mesh, axes,
    rep_axis: str = "pod", cap: Optional[int] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Home-bucket points, then deal each bucket round-robin over ``rep``.

    Returns (R, A, B, cap_r, 3) points and (R, A, B, cap_r) valid masks —
    the input layout ``build_pd(..., rep_axis=...)`` expects.
    """
    A, B = _mesh_sizes(mesh, axes)
    R = mesh.shape[rep_axis]
    pts = np.asarray(points, dtype=np.float32)
    gx_loc, gy_loc = _device_grid_dims(dom, A, B)
    b = bucketing.bucket_points_home(
        pts, dom, (gx_loc, gy_loc, dom.Gt), cap=cap
    )
    na, nb = b.ntiles[0], b.ntiles[1]
    src, val = _pad_tile_grid(
        b.points.reshape(na, nb, b.cap, 3),
        b.valid.reshape(na, nb, b.cap).astype(np.float32), A, B)
    # deal bucket contents over R replicas
    cap_r = bucketing.round_up(max(1, -(-b.cap // R)), 8)
    dpts = np.full((R, A, B, cap_r, 3), PARK, dtype=np.float32)
    dval = np.zeros((R, A, B, cap_r), dtype=np.float32)
    pos = np.arange(b.cap)
    r_of = pos % R
    p_of = pos // R
    dpts[r_of, :, :, p_of] = np.transpose(src, (2, 0, 1, 3))
    dval[r_of, :, :, p_of] = np.transpose(val, (2, 0, 1)).astype(np.float32)
    return jnp.asarray(dpts), jnp.asarray(dval)


def stkde_hybrid(
    points: np.ndarray,
    dom: Domain,
    mesh: Mesh,
    axes: Tuple[str, str] = ("data", "model"),
    rep_axis: str = "pod",
    cap: Optional[int] = None,
    ks: km.SpatialKernel = km.DEFAULT_KS,
    kt: km.TemporalKernel = km.DEFAULT_KT,
    n_total: Optional[int] = None,
) -> jnp.ndarray:
    """PD over the worker grid × DR over the ``rep`` axis (PB-SYM-PD-REP).

    Every bucket's points are dealt round-robin over the rep axis — the
    moldable-task replication of the paper expressed as a mesh dimension.
    """
    pts = np.asarray(points, dtype=np.float32)
    return stkde_pd(
        pts, dom, mesh, axes, cap=cap, ks=ks, kt=kt, n_total=n_total,
        _rep_axis=rep_axis,
        _pts_override=prepare_hybrid(
            pts, dom, mesh, axes, rep_axis=rep_axis, cap=cap),
    )


# ------------------------------------------------------------------ DD-LPT
def prepare_dd_lpt(
    points: np.ndarray, dom: Domain, mesh: Mesh, axes,
    tile: Optional[Tuple[int, int, int]] = None,
    cap: Optional[int] = None,
):
    """Fine-tile bucket + LPT placement for DD-LPT.

    Returns ``((dpts, dval, dpos), ctx)`` where the first element is the
    argument tuple for the jitted builder and ``ctx`` carries the
    point-dependent static parameters (``tile``, ``k``, ``cap``,
    ``ntiles``) that ``build_dd_lpt`` needs to compile.
    """
    A, B = _mesh_sizes(mesh, axes)
    Ptot = A * B
    pts = np.asarray(points, dtype=np.float32)
    if tile is None:
        tile = bucketing.default_tile(dom)
    bx, by, bt = tile
    b = bucketing.bucket_points_overlap(pts, dom, tile, cap=cap)
    ntx, nty, ntt = b.ntiles
    loads = b.counts.reshape(-1).astype(np.float64)
    assign = partition.lpt_assign(loads, Ptot)
    k = max(len(t) for t in assign.tiles_of_device)

    capn = b.cap
    dpts = np.full((Ptot, k, capn, 3), PARK, dtype=np.float32)
    dval = np.zeros((Ptot, k, capn), dtype=np.float32)
    dpos = np.zeros((Ptot, k, 3), dtype=np.int32)
    flat_pts = b.points.reshape(-1, capn, 3)
    flat_val = b.valid.reshape(-1, capn)
    for p, tiles in enumerate(assign.tiles_of_device):
        for s, t in enumerate(tiles):
            ti, tj, tk = np.unravel_index(t, (ntx, nty, ntt))
            dpts[p, s] = flat_pts[t]
            dval[p, s] = flat_val[t]
            dpos[p, s] = (ti * bx, tj * by, tk * bt)
    args = (jnp.asarray(dpts), jnp.asarray(dval), jnp.asarray(dpos))
    ctx = {"tile": tile, "k": k, "cap": capn, "ntiles": b.ntiles}
    return args, ctx


def build_dd_lpt(dom: Domain, mesh: Mesh, axes, n: int,
                 tile: Tuple[int, int, int], k: int, cap: int,
                 ntiles: Tuple[int, int, int],
                 ks=km.DEFAULT_KS, kt=km.DEFAULT_KT,
                 collectives: bool = True):
    """Jitted DD-LPT over LPT-placed tile soup (dry-run lowerable).

    Static parameters (``tile``, ``k``, ``cap``, ``ntiles``) come from
    ``prepare_dd_lpt``'s ctx. ``collectives=False`` skips the tile-soup
    assembly psum and returns the device-stacked partial grids — the
    reconciliation probe for the planner's ``comm_s`` term.
    """
    ax, ay = axes
    bx, by, bt = tile
    ntx, nty, ntt = ntiles
    Gxp, Gyp, Gtp = ntx * bx, nty * by, ntt * bt
    norm = km.normalization(n, dom.hs, dom.ht)

    def one_tile(pts_t, val_t, pos_t):
        """Separable PB-SYM contraction for one (bx, by, bt) tile."""
        xc = dom.ox + (pos_t[0].astype(jnp.float32)
                       + jnp.arange(bx, dtype=jnp.float32) + 0.5) * dom.sres
        yc = dom.oy + (pos_t[1].astype(jnp.float32)
                       + jnp.arange(by, dtype=jnp.float32) + 0.5) * dom.sres
        tc = dom.ot + (pos_t[2].astype(jnp.float32)
                       + jnp.arange(bt, dtype=jnp.float32) + 0.5) * dom.tres
        u = (xc[None, :] - pts_t[:, 0:1]) / dom.hs
        v = (yc[None, :] - pts_t[:, 1:2]) / dom.hs
        w = (tc[None, :] - pts_t[:, 2:3]) / dom.ht
        Ks = ks(u[:, :, None], v[:, None, :]) * norm
        Kt = kt(w) * val_t[:, None]
        return jnp.einsum("pxy,pt->xyt", Ks, Kt)

    def f(pts_blk, val_blk, pos_blk):  # (1,k,cap,3), (1,k,cap), (1,k,3)
        tiles = jax.vmap(one_tile)(pts_blk[0], val_blk[0], pos_blk[0])

        def place(s, g):
            return jax.lax.dynamic_update_slice(
                g,
                jax.lax.dynamic_slice(
                    g,
                    (pos_blk[0, s, 0], pos_blk[0, s, 1], pos_blk[0, s, 2]),
                    (bx, by, bt),
                )
                + tiles[s],
                (pos_blk[0, s, 0], pos_blk[0, s, 1], pos_blk[0, s, 2]),
            )

        g0 = pcast(
            jnp.zeros((Gxp, Gyp, Gtp), jnp.float32), (ax, ay), to="varying"
        )
        g = jax.lax.fori_loop(0, k, place, g0)
        if collectives:
            return jax.lax.psum(g, (ax, ay))
        return g[None]

    out_specs = (
        P(None, None, None) if collectives
        else P((ax, ay), None, None, None)
    )
    return jax.jit(shard_map(
        f,
        mesh=mesh,
        in_specs=(
            P((ax, ay), None, None, None),
            P((ax, ay), None, None),
            P((ax, ay), None, None),
        ),
        out_specs=out_specs,
    ))


def stkde_dd_lpt(
    points: np.ndarray,
    dom: Domain,
    mesh: Mesh,
    axes: Tuple[str, str] = ("data", "model"),
    tile: Optional[Tuple[int, int, int]] = None,
    cap: Optional[int] = None,
    ks: km.SpatialKernel = km.DEFAULT_KS,
    kt: km.TemporalKernel = km.DEFAULT_KT,
    n_total: Optional[int] = None,
) -> jnp.ndarray:
    """Fine-tile DD with LPT load-aware placement (PD-SCHED as placement).

    Each device receives the k tiles LPT assigned to it (capacity-padded
    "tile soup"), computes each tile's density with the separable contraction,
    scatters them into a device-local grid, and the grids are summed — tiles
    are disjoint, so the psum is pure assembly, not numerical reduction.
    """
    pts = np.asarray(points, dtype=np.float32)
    n = int(n_total) if n_total is not None else len(pts)
    args, ctx = prepare_dd_lpt(pts, dom, mesh, axes, tile=tile, cap=cap)
    fn = build_dd_lpt(
        dom, mesh, axes, n, ctx["tile"], ctx["k"], ctx["cap"],
        ctx["ntiles"], ks, kt,
    )
    out = fn(*args)
    return out[: dom.Gx, : dom.Gy, : dom.Gt]


STRATEGIES = {
    "dr": stkde_dr,
    "dd": stkde_dd,
    "pd": stkde_pd,
    "pd_xt": stkde_pd_xt,
    "pd_xyt": stkde_pd_xyt,
    "dd_lpt": stkde_dd_lpt,
    "hybrid": stkde_hybrid,
}


# -------------------------------------------------------------- chunked
def execute_chunk(
    points: np.ndarray,
    dom: Domain,
    mesh: Mesh,
    strategy: str,
    axes: Tuple[str, ...] = ("data", "model"),
    rep_axis: Optional[str] = None,
    cap: Optional[int] = None,
    ks: km.SpatialKernel = km.DEFAULT_KS,
    kt: km.TemporalKernel = km.DEFAULT_KT,
    n_total: Optional[int] = None,
) -> jnp.ndarray:
    """One chunk of a chunked run on the current mesh (normalized by the
    *global* ``n_total``).

    The ``dist.device`` fault site models a device dying mid-chunk: an
    injected oom/drop here surfaces as a non-transient ``DeviceLostError``
    so the chunked executor (``core.api.stkde_chunked``) re-plans the
    remaining chunks onto a shrunken mesh instead of retrying a dead one.
    """
    from repro.resilience.errors import DeviceLostError, FaultInjectedError

    shape = tuple(mesh.shape[a] for a in mesh.axis_names)
    try:
        _faults.fault_point("dist.device")
    except FaultInjectedError as e:
        raise DeviceLostError("dist.device", mesh_shape=shape) from e
    fn = STRATEGIES[strategy]
    kw = dict(axes=axes, ks=ks, kt=kt, n_total=n_total)
    if strategy == "hybrid":
        kw["rep_axis"] = rep_axis or "pod"
    if strategy == "pd_xyt" and len(axes) == 2:
        # 3-D split needs a third mesh axis: the rep axis becomes the X cut
        kw["axes"] = (rep_axis or "pod",) + tuple(axes)
    if cap is not None and strategy in ("dd", "pd", "pd_xt", "pd_xyt"):
        # fixed bucket capacity keeps the jitted shapes identical across
        # chunks (one compile per (strategy, mesh), not per chunk)
        kw["cap"] = cap
    return fn(points, dom, mesh, **kw)
