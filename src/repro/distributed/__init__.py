"""Distributed STKDE strategies and placement machinery (shard_map)."""
from . import partition
from .stkde_dist import (
    stkde_dr,
    stkde_dd,
    stkde_pd,
    stkde_dd_lpt,
    stkde_hybrid,
    STRATEGIES,
)

__all__ = [
    "partition",
    "stkde_dr",
    "stkde_dd",
    "stkde_pd",
    "stkde_dd_lpt",
    "stkde_hybrid",
    "STRATEGIES",
]
