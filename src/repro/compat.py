"""Version shims for the installed JAX.

The codebase is written against the modern JAX surface (``jax.shard_map``,
``jax.sharding.AxisType``, ``jax.lax.pcast``, ``jax.typeof``,
``make_mesh(..., axis_types=...)``). Older releases (e.g. 0.4.x, where
shard_map still lives in ``jax.experimental``) lack several of those names;
this module resolves each one once, preferring the modern spelling, and
backfills the handful that tests and benchmark subprocesses import straight
from ``jax.*`` so one source tree runs on both.

Import side effects are limited to adding missing attributes on ``jax`` /
``jax.sharding`` — nothing that exists is ever overwritten.
"""
from __future__ import annotations

import enum
import functools
import inspect
from typing import Any, FrozenSet

import jax


# ----------------------------------------------------------------- shard_map
if hasattr(jax, "shard_map"):
    _shard_map_impl = jax.shard_map
else:  # JAX <= 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map_impl

_SM_PARAMS = set(inspect.signature(_shard_map_impl).parameters)


def shard_map(f=None, **kw):
    """``jax.shard_map`` resolved across versions.

    Translates the modern ``check_vma=`` kwarg to the legacy ``check_rep=``
    when the installed shard_map predates the rename, and drops kwargs the
    installed version does not know about.
    """
    if "check_vma" in kw and "check_vma" not in _SM_PARAMS:
        kw["check_rep"] = kw.pop("check_vma")
    kw = {k: v for k, v in kw.items() if k in _SM_PARAMS}
    if f is None:
        return functools.partial(_shard_map_impl, **kw)
    return _shard_map_impl(f, **kw)


# ------------------------------------------------------------------- pcast
def pcast(x, axes, to: str = "varying"):
    """``jax.lax.pcast`` where available; identity otherwise.

    Legacy shard_map's replication checker (``check_rep``) tracks
    replicated-vs-varying without explicit casts, so dropping the cast is
    semantically a no-op there.
    """
    fn = getattr(jax.lax, "pcast", None)
    if fn is None:
        return x
    return fn(x, axes, to=to)


def vma_of(x) -> FrozenSet[str]:
    """The varying-manual-axes set of a traced value (empty pre-``typeof``)."""
    typeof = getattr(jax, "typeof", None)
    if typeof is None:
        return frozenset()
    return getattr(typeof(x), "vma", frozenset())


if not hasattr(jax, "shard_map"):
    jax.shard_map = shard_map  # type: ignore[attr-defined]


# ------------------------------------------------- optimization_barrier
@jax.custom_jvp
def optimization_barrier(x):
    """``lax.optimization_barrier`` with a differentiation rule.

    Old JAX has no JVP rule for the barrier primitive; the barrier is
    semantically the identity, so the tangent passes through unchanged.
    """
    return jax.lax.optimization_barrier(x)


@optimization_barrier.defjvp
def _optimization_barrier_jvp(primals, tangents):
    (x,), (t,) = primals, tangents
    return jax.lax.optimization_barrier(x), t


# ---------------------------------------------------------------- AxisType
if not hasattr(jax.sharding, "AxisType"):
    class AxisType(enum.Enum):
        """Stand-in for ``jax.sharding.AxisType`` (all axes were implicitly
        Auto before explicit-sharding landed)."""

        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"

    jax.sharding.AxisType = AxisType  # type: ignore[attr-defined]
else:
    AxisType = jax.sharding.AxisType


# --------------------------------------------------------------- make_mesh
_orig_make_mesh = jax.make_mesh
if "axis_types" not in inspect.signature(_orig_make_mesh).parameters:
    @functools.wraps(_orig_make_mesh)
    def _make_mesh(axis_shapes, axis_names, *args, axis_types=None, **kw):
        if axis_types is not None and any(
            t is not AxisType.Auto for t in axis_types
        ):
            raise NotImplementedError(
                "installed JAX predates explicit/manual mesh axis types"
            )
        return _orig_make_mesh(axis_shapes, axis_names, *args, **kw)

    jax.make_mesh = _make_mesh

make_mesh = jax.make_mesh


def default_axis_types(n: int) -> tuple:
    """(AxisType.Auto,) * n — the common mesh construction argument."""
    return (AxisType.Auto,) * n


__all__ = [
    "shard_map",
    "pcast",
    "vma_of",
    "AxisType",
    "make_mesh",
    "default_axis_types",
]
