"""Fault-tolerant checkpointing: sharded save, async, latest-resume, elastic.

Design (single-process container stands in for per-host writers):
  * A checkpoint is a directory ``step_<N>/`` holding one .npz per top-level
    param/opt group plus a JSON manifest (structure, step, mesh shape).
    On a multi-host deployment each host writes only its addressable shards
    (the manifest records the global shapes, so restore re-shards freely).
  * ``save_async`` snapshots device arrays to host then writes on a
    background thread — the train loop never blocks on I/O.
  * Restore is **elastic**: arrays are loaded as full host arrays and then
    placed with whatever sharding the *current* mesh requires
    (``jax.device_put`` with NamedSharding) — a 512-chip checkpoint restores
    onto 256 chips (or 8 CPU devices in tests) unchanged.
  * ``latest_step`` + atomic rename give crash-consistent resume: a dir is
    visible only after its manifest lands (write-tmp, fsync, rename).
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
from typing import Any, Optional, Tuple

import jax
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)) and not hasattr(tree, "_fields"):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    elif hasattr(tree, "_fields"):  # NamedTuple
        for k in tree._fields:
            out.update(_flatten(getattr(tree, k), f"{prefix}{k}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten_into(template, flat, prefix=""):
    if isinstance(template, dict):
        return {
            k: _unflatten_into(v, flat, f"{prefix}{k}/")
            for k, v in template.items()
        }
    if hasattr(template, "_fields"):
        return type(template)(*(
            _unflatten_into(getattr(template, k), flat, f"{prefix}{k}/")
            for k in template._fields
        ))
    if isinstance(template, (list, tuple)):
        return type(template)(
            _unflatten_into(v, flat, f"{prefix}{i}/")
            for i, v in enumerate(template)
        )
    return flat[prefix[:-1]]


def _encode(arr: np.ndarray) -> np.ndarray:
    """npz-safe encoding: non-native dtypes (bf16, fp8) go as byte views."""
    if arr.dtype.kind == "V" or arr.dtype.name not in np.sctypeDict:
        return arr.view(np.uint8)
    return arr


def save(ckpt_dir: str, step: int, tree: Any, extra: Optional[dict] = None):
    """Synchronous crash-consistent save of a pytree."""
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = _flatten(tree)
    host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
    dtypes = {k: v.dtype.name for k, v in host.items()}
    shapes = {k: list(v.shape) for k, v in host.items()}
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=f".tmp_step_{step}_")
    try:
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{k.replace("/", "__"): _encode(v)
                    for k, v in host.items()})
        manifest = {
            "step": int(step),
            "keys": sorted(host),
            "dtypes": dtypes,
            "shapes": shapes,
            "extra": extra or {},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        final = os.path.join(ckpt_dir, f"step_{step:08d}")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return os.path.join(ckpt_dir, f"step_{step:08d}")


class AsyncCheckpointer:
    """Snapshot-to-host then write on a daemon thread; join on demand."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, tree: Any, extra: Optional[dict] = None):
        self.wait()
        # snapshot on the caller thread (device -> host is the sync point)
        flat = _flatten(tree)
        host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}

        def write():
            try:
                snap = _unflatten_into(tree, host)
                save(self.ckpt_dir, step, snap, extra)
                self._gc()
            except BaseException as e:   # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=write, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            e, self._error = self._error, None
            raise e

    def _gc(self):
        steps = all_steps(self.ckpt_dir)
        for s in steps[: -self.keep]:
            shutil.rmtree(
                os.path.join(self.ckpt_dir, f"step_{s:08d}"),
                ignore_errors=True,
            )


def all_steps(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and os.path.exists(
            os.path.join(ckpt_dir, d, "manifest.json")
        ):
            out.append(int(d[len("step_"):]))
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(
    ckpt_dir: str,
    template: Any,
    step: Optional[int] = None,
    shardings: Any = None,
) -> Tuple[Any, int, dict]:
    """Restore into ``template``'s structure; optionally place with
    ``shardings`` (elastic reshard onto the current mesh)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    dtypes = manifest.get("dtypes", {})
    shapes = manifest.get("shapes", {})
    import ml_dtypes  # noqa: F401 — registers bf16/fp8 numpy dtypes

    with np.load(os.path.join(path, "arrays.npz")) as z:
        flat = {}
        for k in z.files:
            key = k.replace("__", "/")
            arr = z[k]
            want = dtypes.get(key)
            if want and arr.dtype.name != want:
                arr = arr.view(np.dtype(want)).reshape(shapes[key])
            flat[key] = arr
    tree = _unflatten_into(template, flat)
    if shardings is not None:
        tree = jax.tree.map(
            lambda a, s: jax.device_put(a, s), tree, shardings
        )
    return tree, step, manifest.get("extra", {})
