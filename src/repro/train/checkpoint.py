"""Fault-tolerant checkpointing: sharded save, async, latest-resume, elastic.

Design (single-process container stands in for per-host writers):
  * A checkpoint is a directory ``step_<N>/`` holding one .npz per top-level
    param/opt group plus a JSON manifest (structure, step, mesh shape).
    On a multi-host deployment each host writes only its addressable shards
    (the manifest records the global shapes, so restore re-shards freely).
  * ``save_async`` snapshots device arrays to host then writes on a
    background thread — the train loop never blocks on I/O.
  * Restore is **elastic**: arrays are loaded as full host arrays and then
    placed with whatever sharding the *current* mesh requires
    (``jax.device_put`` with NamedSharding) — a 512-chip checkpoint restores
    onto 256 chips (or 8 CPU devices in tests) unchanged.
  * ``latest_step`` + atomic rename give crash-consistent resume: a dir is
    visible only after its manifest lands (write-tmp, fsync, rename).

Resilience (docs/resilience.md):
  * The manifest records a CRC-32 of the array payload; writes are
    verified by re-reading the landed bytes before the atomic rename and
    retried (``resilience.retry``) on mismatch — the ``ckpt.write`` fault
    site corrupts the payload in flight to exercise exactly this path.
  * ``restore(step=None)`` walks checkpoints newest→oldest and falls back
    past truncated/bit-flipped/unreadable ones (``resilience.ckpt_fallback``
    counter), so one bad write never strands a resume.
  * ``save(..., keep=K)`` prunes to the newest K checkpoints after a
    successful landing (never before).
"""
from __future__ import annotations

import io
import json
import os
import shutil
import tempfile
import threading
import zlib
from typing import Any, Optional, Tuple

import jax
import numpy as np

from repro.obs import metrics as obs_metrics
from repro.resilience import faults as _faults
from repro.resilience import retry as _retry
from repro.resilience.errors import CheckpointCorruptError


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)) and not hasattr(tree, "_fields"):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    elif hasattr(tree, "_fields"):  # NamedTuple
        for k in tree._fields:
            out.update(_flatten(getattr(tree, k), f"{prefix}{k}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten_into(template, flat, prefix=""):
    if isinstance(template, dict):
        return {
            k: _unflatten_into(v, flat, f"{prefix}{k}/")
            for k, v in template.items()
        }
    if hasattr(template, "_fields"):
        return type(template)(*(
            _unflatten_into(getattr(template, k), flat, f"{prefix}{k}/")
            for k in template._fields
        ))
    if isinstance(template, (list, tuple)):
        return type(template)(
            _unflatten_into(v, flat, f"{prefix}{i}/")
            for i, v in enumerate(template)
        )
    return flat[prefix[:-1]]


def _encode(arr: np.ndarray) -> np.ndarray:
    """npz-safe encoding: non-native dtypes (bf16, fp8) go as byte views."""
    if arr.dtype.kind == "V" or arr.dtype.name not in np.sctypeDict:
        return arr.view(np.uint8)
    return arr


_WRITE_POLICY = _retry.RetryPolicy(max_attempts=5, base_delay_s=0.01,
                                   max_delay_s=0.2)


def save(ckpt_dir: str, step: int, tree: Any, extra: Optional[dict] = None,
         keep: Optional[int] = None):
    """Crash-consistent save: serialize, write-verify (CRC), atomic rename.

    The write is retried under ``_WRITE_POLICY`` when the landed bytes
    fail verification (injected or real corruption); ``keep`` prunes to
    the newest K checkpoints after this one lands.
    """
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = _flatten(tree)
    host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
    dtypes = {k: v.dtype.name for k, v in host.items()}
    shapes = {k: list(v.shape) for k, v in host.items()}
    buf = io.BytesIO()
    np.savez(buf, **{k.replace("/", "__"): _encode(v)
                     for k, v in host.items()})
    payload = buf.getvalue()
    checksum = zlib.crc32(payload)
    manifest = {
        "step": int(step),
        "keys": sorted(host),
        "dtypes": dtypes,
        "shapes": shapes,
        "checksum_crc32": checksum,
        "extra": extra or {},
    }

    def write_once() -> str:
        _faults.fault_point("ckpt.write")
        tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=f".tmp_step_{step}_")
        try:
            apath = os.path.join(tmp, "arrays.npz")
            with open(apath, "wb") as f:
                # the ckpt.write fault site bit-flips the payload in
                # flight; the read-back below catches it pre-rename
                f.write(_faults.corrupt("ckpt.write", payload))
                f.flush()
                os.fsync(f.fileno())
            with open(apath, "rb") as f:
                landed = zlib.crc32(f.read())
            if landed != checksum:
                raise CheckpointCorruptError(
                    f"step {step}: landed crc {landed:#x} != "
                    f"{checksum:#x} (write corrupted)"
                )
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
                f.flush()
                os.fsync(f.fileno())
            final = os.path.join(ckpt_dir, f"step_{step:08d}")
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            return final
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise

    final = _retry.with_retry(write_once, policy=_WRITE_POLICY,
                              site="ckpt.write")
    if keep is not None:
        gc_steps(ckpt_dir, keep)
    return final


def gc_steps(ckpt_dir: str, keep: int) -> None:
    """Prune to the newest ``keep`` checkpoints."""
    for s in all_steps(ckpt_dir)[:-keep]:
        shutil.rmtree(
            os.path.join(ckpt_dir, f"step_{s:08d}"), ignore_errors=True
        )


class AsyncCheckpointer:
    """Snapshot-to-host then write on a daemon thread; join on demand."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, tree: Any, extra: Optional[dict] = None):
        self.wait()
        # snapshot on the caller thread (device -> host is the sync point)
        flat = _flatten(tree)
        host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}

        def write():
            try:
                snap = _unflatten_into(tree, host)
                save(self.ckpt_dir, step, snap, extra, keep=self.keep)
            except BaseException as e:   # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=write, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            e, self._error = self._error, None
            raise e


def all_steps(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and os.path.exists(
            os.path.join(ckpt_dir, d, "manifest.json")
        ):
            out.append(int(d[len("step_"):]))
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def verify(ckpt_dir: str, step: int) -> bool:
    """Cheap integrity check: manifest parses and the payload CRC matches
    (checkpoints written before checksums are accepted as-is)."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    try:
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        want = manifest.get("checksum_crc32")
        if want is None:
            return True
        with open(os.path.join(path, "arrays.npz"), "rb") as f:
            return zlib.crc32(f.read()) == want
    except (OSError, ValueError):
        return False


def _load_step(path: str, template: Any) -> Tuple[Any, dict]:
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    apath = os.path.join(path, "arrays.npz")
    want = manifest.get("checksum_crc32")
    if want is not None:
        with open(apath, "rb") as f:
            got = zlib.crc32(f.read())
        if got != want:
            raise CheckpointCorruptError(
                f"{path}: payload crc {got:#x} != manifest {want:#x}"
            )
    dtypes = manifest.get("dtypes", {})
    shapes = manifest.get("shapes", {})
    import ml_dtypes  # noqa: F401 — registers bf16/fp8 numpy dtypes

    with np.load(apath) as z:
        flat = {}
        for k in z.files:
            key = k.replace("__", "/")
            arr = z[k]
            want_dt = dtypes.get(key)
            if want_dt and arr.dtype.name != want_dt:
                arr = arr.view(np.dtype(want_dt)).reshape(shapes[key])
            flat[key] = arr
    return _unflatten_into(template, flat), manifest


def restore(
    ckpt_dir: str,
    template: Any,
    step: Optional[int] = None,
    shardings: Any = None,
) -> Tuple[Any, int, dict]:
    """Restore into ``template``'s structure; optionally place with
    ``shardings`` (elastic reshard onto the current mesh).

    With ``step=None``, walks checkpoints newest→oldest and skips
    corrupt/unreadable ones (``resilience.ckpt_fallback`` counts each
    skip); an explicit ``step`` is loaded strictly and raises
    ``CheckpointCorruptError`` on damage.
    """
    if step is not None:
        candidates = [step]
        strict = True
    else:
        candidates = list(reversed(all_steps(ckpt_dir)))
        strict = False
        if not candidates:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    last_err: Optional[BaseException] = None
    for s in candidates:
        path = os.path.join(ckpt_dir, f"step_{s:08d}")
        try:
            tree, manifest = _load_step(path, template)
        except (CheckpointCorruptError, OSError, ValueError, KeyError,
                zlib.error) as e:
            if strict:
                if isinstance(e, CheckpointCorruptError):
                    raise
                raise CheckpointCorruptError(f"{path}: {e}") from e
            obs_metrics.counter("resilience.ckpt_fallback").inc()
            last_err = e
            continue
        if shardings is not None:
            tree = jax.tree.map(
                lambda a, sh: jax.device_put(a, sh), tree, shardings
            )
        return tree, s, manifest.get("extra", {})
    raise CheckpointCorruptError(
        f"no valid checkpoint in {ckpt_dir} "
        f"(tried {len(candidates)}; last: {last_err})"
    )
