"""Train-step factory: CE loss (+ router aux), grads, AdamW — pjit-ready.

``make_train_step(cfg, opt_cfg)`` returns a pure function
``(params, opt_state, batch) -> (params, opt_state, metrics)`` suitable for
``jax.jit`` with param/batch shardings (launch/train.py, launch/dryrun.py).

Optionally composes int8 error-feedback gradient compression on the "pod"
axis (cross-DCN) via shard_map around the gradient reduction.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import forward
from . import optimizer as opt


def cross_entropy(logits, labels, mask=None):
    """Mean CE over valid positions; logits fp32 (B, S, V).

    Sharding-aware formulation: the label logit is picked with a one-hot
    select-and-reduce rather than take_along_axis, so with vocab-sharded
    logits every reduction is over the sharded axis and GSPMD emits only
    (B, S)-sized psums — the full logits tensor is never gathered
    (§Perf iteration 1).
    """
    m = jax.lax.stop_gradient(logits.max(axis=-1, keepdims=True))
    shifted = logits - m
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1))
    vocab_iota = jax.lax.broadcasted_iota(
        labels.dtype, logits.shape, logits.ndim - 1)
    label_logit = jnp.sum(
        jnp.where(vocab_iota == labels[..., None], shifted, 0.0), axis=-1)
    nll = lse - label_logit
    if mask is None:
        return nll.mean()
    mask = mask.astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def z_loss(logits, coef: float = 1e-4):
    """Stabilizes the softmax normalizer at scale (PaLM-style)."""
    z = jax.nn.logsumexp(logits, axis=-1)
    return coef * jnp.mean(jnp.square(z))


def make_loss_fn(cfg):
    def loss_fn(params, batch):
        kw = {}
        if cfg.frontend == "vision":
            kw["vision_embeds"] = batch["vision_embeds"]
        if cfg.enc_dec:
            kw["audio_frames"] = batch["audio_frames"]
        logits, aux = forward(cfg, params, batch["tokens"], **kw)
        # vlm: image prefix positions carry no labels
        logits = logits[:, -batch["tokens"].shape[1]:]
        loss = cross_entropy(logits, batch["labels"], batch.get("mask"))
        total = loss + aux + z_loss(logits)
        return total, {"ce": loss, "aux": aux}

    return loss_fn


def make_train_step(cfg, opt_cfg: opt.OptimizerConfig):
    loss_fn = make_loss_fn(cfg)

    def train_step(params, opt_state, batch):
        (total, parts), grads = jax.value_and_grad(
            loss_fn, has_aux=True
        )(params, batch)
        params, opt_state, metrics = opt.update(
            opt_cfg, params, grads, opt_state
        )
        metrics.update(parts, loss=total)
        return params, opt_state, metrics

    return train_step


def make_eval_step(cfg):
    loss_fn = make_loss_fn(cfg)

    def eval_step(params, batch):
        total, parts = loss_fn(params, batch)
        return dict(parts, loss=total)

    return eval_step
