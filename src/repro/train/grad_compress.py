"""Int8 gradient compression with error feedback — for cross-pod (DCN)
all-reduce.

At 2 pods × 256 chips the DCN gradient all-reduce is the slowest collective
in the train step. Per-tensor-scaled int8 quantization cuts DCN bytes 4x;
the quantization residual is carried into the next step (error feedback),
which keeps SGD-style convergence (Seide et al. 2014; 1-bit Adam lineage).

Usage inside a shard_map'd train step:

    g_q, err = compress(g + err)                 # quantize with feedback
    g_sum = jax.lax.psum(g_q.astype(f32), "pod") # DCN all-reduce in int8 (*)
    g = dequantize(g_sum)

(*) With pjit/GSPMD the psum operand dtype drives the collective payload;
we expose both the quantize/dequantize pair and a psum wrapper.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class CompressState(NamedTuple):
    error: Any     # residual pytree (fp32), same structure as grads


def init(grads_shape) -> CompressState:
    return CompressState(
        error=jax.tree.map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads_shape
        )
    )


def quantize(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-tensor symmetric int8. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(g)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compress_tree(grads, state: CompressState):
    """Quantize grads+feedback; returns (q_tree, scales, new_state)."""
    fed = jax.tree.map(
        lambda g, e: g.astype(jnp.float32) + e, grads, state.error
    )
    qs = jax.tree.map(quantize, fed)
    q_tree = jax.tree.map(lambda t: t[0], qs,
                          is_leaf=lambda x: isinstance(x, tuple))
    scales = jax.tree.map(lambda t: t[1], qs,
                          is_leaf=lambda x: isinstance(x, tuple))
    deq = jax.tree.map(dequantize, q_tree, scales)
    new_err = jax.tree.map(lambda f, d: f - d, fed, deq)
    return q_tree, scales, CompressState(error=new_err)


def psum_compressed(grads, state: CompressState, axis_name: str):
    """Error-feedback-compressed psum over ``axis_name`` (the pod axis).

    Scheme: (1) scalar pmax agrees on one per-tensor scale (cheap — one
    scalar per tensor on the wire), (2) every participant quantizes with the
    shared scale, (3) the int8 payload is summed (int32 accumulate), (4)
    dequantize once. Quantization residuals go into error feedback.
    """
    fed = jax.tree.map(
        lambda g, e: g.astype(jnp.float32) + e, grads, state.error
    )
    shared_scale = jax.tree.map(
        lambda g: jax.lax.pmax(jnp.max(jnp.abs(g)) + 1e-12, axis_name)
        / 127.0,
        fed,
    )
    q_tree = jax.tree.map(
        lambda g, s: jnp.clip(jnp.round(g / s), -127, 127).astype(jnp.int8),
        fed, shared_scale,
    )
    new_err = jax.tree.map(
        lambda f, q, s: f - q.astype(jnp.float32) * s,
        fed, q_tree, shared_scale,
    )
    summed = jax.tree.map(
        lambda q: jax.lax.psum(q.astype(jnp.int32), axis_name), q_tree
    )
    out = jax.tree.map(
        lambda q, s: q.astype(jnp.float32) * s, summed, shared_scale
    )
    return out, CompressState(error=new_err)
