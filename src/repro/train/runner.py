"""Fault-tolerant training runner.

Wraps the pure train step with the operational machinery a 1000-node run
needs:

  * auto-resume from the latest checkpoint (crash / preemption restart)
  * periodic async checkpoints (never blocks the step)
  * preemption hook (SIGTERM -> synchronous final checkpoint -> exit)
  * straggler / hang detection: per-step wall-time EWMA; steps slower than
    ``straggler_factor``× the EWMA are logged with their step index (on a
    real pod this feeds the reschedule/hot-standby controller; here it is a
    log + counter the tests assert on)
  * NaN-loss circuit breaker: skip the update and (optionally) restore

Step timing flows through ``repro.obs`` (span ``train.step``, histogram
``train.step_s``) so runner wall times share one code path with the
benchmarks and show up in the Chrome trace.
"""
from __future__ import annotations

import dataclasses
import signal
from typing import Any, Callable, Iterable, Optional

import jax
import numpy as np

from repro import obs

from . import checkpoint as ckpt_lib


@dataclasses.dataclass
class RunnerConfig:
    ckpt_dir: str
    ckpt_every: int = 100
    keep: int = 3
    max_steps: int = 1000
    straggler_factor: float = 3.0
    log_every: int = 10
    resume: bool = True


class TrainRunner:
    def __init__(
        self,
        run_cfg: RunnerConfig,
        train_step: Callable,     # (params, opt_state, batch) -> (p, o, m)
        params: Any,
        opt_state: Any,
        log: Callable[[str], None] = print,
    ):
        self.cfg = run_cfg
        self.train_step = train_step
        self.params = params
        self.opt_state = opt_state
        self.log = log
        self.step = 0
        self.straggler_events = []
        self.metrics_history = []
        self._ckpt = ckpt_lib.AsyncCheckpointer(run_cfg.ckpt_dir,
                                                keep=run_cfg.keep)
        self._preempted = False
        if run_cfg.resume:
            self._maybe_resume()

    # ------------------------------------------------------------- resume
    def _maybe_resume(self):
        last = ckpt_lib.latest_step(self.cfg.ckpt_dir)
        if last is None:
            return
        (self.params, self.opt_state), self.step, _ = ckpt_lib.restore(
            self.cfg.ckpt_dir, (self.params, self.opt_state), step=last
        )
        self.step = last
        self.log(f"[runner] resumed from step {last}")

    # --------------------------------------------------------- preemption
    def install_preemption_hook(self):
        def handler(signum, frame):
            self._preempted = True
            self.log("[runner] SIGTERM: checkpointing before exit")

        signal.signal(signal.SIGTERM, handler)

    # ------------------------------------------------------------- train
    def run(self, batches: Iterable[Any]) -> dict:
        ewma = None
        for batch in batches:
            if self.step >= self.cfg.max_steps or self._preempted:
                break
            with obs.span("train.step", step=self.step) as sp:
                params, opt_state, metrics = self.train_step(
                    self.params, self.opt_state, batch
                )
                loss = float(metrics["loss"])
                sp.set(loss=loss)
                if not np.isfinite(loss):
                    self.log(f"[runner] step {self.step}: non-finite loss "
                             f"{loss}; skipping update")
                    obs.counter("train.nonfinite_steps").inc()
                    self.step += 1
                    continue
                self.params, self.opt_state = params, opt_state
                jax.block_until_ready(metrics["loss"])
            dt = sp.duration_s
            obs.histogram("train.step_s").observe(dt)
            obs.counter("train.steps").inc()
            if ewma is None:
                ewma = dt
            elif dt > self.cfg.straggler_factor * ewma:
                self.straggler_events.append((self.step, dt, ewma))
                obs.counter("train.stragglers").inc()
                self.log(f"[runner] straggler step {self.step}: "
                         f"{dt * 1e3:.1f}ms vs ewma {ewma * 1e3:.1f}ms")
                # do not poison the EWMA with the outlier
            else:
                ewma = 0.9 * ewma + 0.1 * dt
            self.step += 1
            self.metrics_history.append(
                {k: float(v) for k, v in metrics.items()}
            )
            if self.step % self.cfg.log_every == 0:
                self.log(
                    f"[runner] step {self.step} loss {loss:.4f} "
                    f"({dt * 1e3:.0f}ms)"
                )
            if self.step % self.cfg.ckpt_every == 0:
                self._ckpt.save(self.step, (self.params, self.opt_state))
        # final (synchronous) checkpoint — also the preemption path
        self._ckpt.wait()
        ckpt_lib.save(self.cfg.ckpt_dir, self.step,
                      (self.params, self.opt_state))
        return {
            "final_step": self.step,
            "stragglers": len(self.straggler_events),
            "last_loss": (self.metrics_history[-1]["loss"]
                          if self.metrics_history else float("nan")),
        }
