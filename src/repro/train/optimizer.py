"""AdamW optimizer + LR schedule + global-norm clipping (pure pytrees).

No optax dependency: states are plain dicts so checkpointing / resharding /
compression wrappers stay trivial. Moments are fp32 regardless of param
dtype (mixed-precision convention in DESIGN.md §6).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    min_lr_frac: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


class OptState(NamedTuple):
    mu: Any
    nu: Any
    step: jnp.ndarray


def lr_at(cfg: OptimizerConfig, step) -> jnp.ndarray:
    """Linear warmup -> cosine decay to min_lr_frac."""
    step = jnp.asarray(step, jnp.float32)
    warm = cfg.lr * step / jnp.maximum(1.0, cfg.warmup_steps)
    prog = jnp.clip(
        (step - cfg.warmup_steps)
        / jnp.maximum(1.0, cfg.total_steps - cfg.warmup_steps),
        0.0, 1.0,
    )
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog)
    )
    return jnp.where(step < cfg.warmup_steps, warm, cfg.lr * cos)


def init(params) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(mu=zeros, nu=jax.tree.map(jnp.copy, zeros),
                    step=jnp.zeros((), jnp.int32))


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(tree))
    )


def _decay_mask(path) -> bool:
    """No weight decay on norms / biases / scalars (1-D leaves)."""
    return True


def update(
    cfg: OptimizerConfig,
    params,
    grads,
    state: OptState,
) -> Tuple[Any, OptState, dict]:
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    lr = lr_at(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def leaf(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        upd = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        decay = cfg.weight_decay if p.ndim >= 2 else 0.0
        new_p = p.astype(jnp.float32) - lr * (upd + decay
                                              * p.astype(jnp.float32))
        return new_p.astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.mu)
    flat_v = jax.tree.leaves(state.nu)
    out = [leaf(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, OptState(mu=new_m, nu=new_v, step=step), metrics
