"""Training substrate: optimizer, train step, checkpointing, runner."""
from . import optimizer, train_step, checkpoint, runner, grad_compress
from .optimizer import OptimizerConfig
from .train_step import make_train_step, make_eval_step, make_loss_fn
from .runner import TrainRunner, RunnerConfig
