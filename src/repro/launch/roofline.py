"""Roofline analysis from compiled AOT artifacts (TPU v5e targets).

Three terms per (arch × shape × mesh), in seconds:

    compute    = HLO_FLOPs      / (chips × 197 TFLOP/s)
    memory     = HLO_bytes      / (chips × 819 GB/s)
    collective = coll_bytes     / (chips × 50 GB/s/link)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()``; collective
bytes are parsed from the post-SPMD HLO text (result shapes × op-specific
ring-traffic multipliers × replica-group sizes).

Scan correction: XLA cost analysis counts a while-loop body ONCE, so
scanned-over-layers models under-report by ~n_layers. The depth-delta method
compiles the same cell with layers unrolled at two shallow depths d1 < d2
and extrapolates  total(L) = f(d1) + (L - d1)/(d2 - d1) × (f(d2) - f(d1)) —
exact for homogeneous stacks, and exact-per-period for zamba2's
every-6-layers shared-attention pattern when d2 - d1 is one period.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

# ------------------------------------------------------ hardware constants
PEAK_FLOPS = 197e12      # bf16 per chip
HBM_BW = 819e9           # bytes/s per chip
ICI_BW = 50e9            # bytes/s per link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s+(?:\()?([a-z0-9]+)\[([0-9,]*)\][^ ]*\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(",
)
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d.strip():
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def parse_collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-device link traffic (bytes) by collective kind.

    Post-SPMD HLO carries per-device shapes. Ring-model per-device traffic:
      all-reduce      2·S·(G-1)/G          (reduce-scatter + all-gather)
      all-gather      S·(G-1)/G            (S = gathered result)
      reduce-scatter  S·(G-1)               (S = scattered shard)
      all-to-all      S·(G-1)/G
      collective-permute  S                 (one neighbor hop)
    """
    out: Dict[str, float] = {
        "all-reduce": 0.0, "all-gather": 0.0, "reduce-scatter": 0.0,
        "all-to-all": 0.0, "collective-permute": 0.0,
    }
    counts: Dict[str, int] = {k: 0 for k in out}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        dtype, dims, kind = m.groups()
        size = _shape_bytes(dtype, dims)
        g = 1
        gm = _GROUPS_RE.search(line)
        if gm:
            g = len(gm.group(1).split(","))
        else:
            gi = _GROUPS_IOTA_RE.search(line)
            if gi:
                g = int(gi.group(2))
        if kind == "all-reduce":
            traffic = 2 * size * (g - 1) / max(g, 1)
        elif kind == "all-gather":
            traffic = size * (g - 1) / max(g, 1)
        elif kind == "reduce-scatter":
            traffic = size * (g - 1)
        elif kind == "all-to-all":
            traffic = size * (g - 1) / max(g, 1)
        else:  # collective-permute
            traffic = size
        out[kind] += traffic
        counts[kind] += 1
    out["total"] = sum(out.values())
    out["n_ops"] = sum(counts.values())
    return out


@dataclasses.dataclass
class Roofline:
    flops: float              # whole-program FLOPs (all chips)
    hbm_bytes: float          # whole-program HBM traffic (all chips)
    coll_bytes_per_dev: float
    chips: int
    model_flops: float = 0.0  # analytic 6ND

    @property
    def compute_s(self) -> float:
        return self.flops / (self.chips * PEAK_FLOPS)

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / (self.chips * HBM_BW)

    @property
    def collective_s(self) -> float:
        # per-device traffic / per-link bandwidth == total/(chips·links)
        return self.coll_bytes_per_dev / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """No-overlap bound: the max term (perfect overlap of the rest)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / self.flops if self.flops else 0.0

    @property
    def mfu_bound(self) -> float:
        """Roofline-implied MFU: useful flops / (chips · peak · step_time)."""
        t = self.step_time_s
        if t <= 0:
            return 0.0
        return self.model_flops / (self.chips * PEAK_FLOPS * t)

    def to_dict(self) -> dict:
        return {
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "coll_bytes_per_dev": self.coll_bytes_per_dev,
            "chips": self.chips,
            "model_flops": self.model_flops,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "step_time_s": self.step_time_s,
            "useful_flops_ratio": self.useful_flops_ratio,
            "mfu_bound": self.mfu_bound,
        }


def algo_flops(cfg, shape_kind: str, seq: int, batch: int) -> float:
    """FLOPs of the *implemented* algorithm (fwd; train = 3x).

    Needed because XLA cost_analysis counts while-loop bodies once: the
    layer scan is corrected by the depth-delta compiles, but inner chunk
    scans (flash attention, SSD, RWKV) would still undercount, so the
    compute roofline term uses this analytic accounting (cross-checked
    against the delta-corrected HLO numbers in EXPERIMENTS.md).
    """
    D, F, V, L = cfg.d_model, cfg.d_ff, cfg.vocab, cfg.n_layers
    H, Hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    decode = shape_kind == "decode"
    tokens = batch * (1 if decode else seq)
    ctx = seq                                # cache length for decode

    per_tok = 0.0
    # ---- token mixer
    if cfg.mixer == "attn":
        if cfg.mla:
            r, dn, dr_, dv = (cfg.kv_lora, cfg.qk_nope_dims,
                              cfg.qk_rope_dims, cfg.v_head_dim)
            per_tok += 2 * D * H * (dn + dr_) + 2 * D * (r + dr_)
            if decode:
                per_tok += 2 * H * dn * r + 2 * H * r * dv
                per_tok += 2 * ctx * H * (r + dr_) + 2 * ctx * H * r
            else:
                per_tok += 2 * r * H * (dn + dv)
                per_tok += 0.5 * (2 * ctx * H * (dn + dr_)
                                  + 2 * ctx * H * dv) * 2
            per_tok += 2 * H * dv * D
        else:
            per_tok += 2 * D * H * dh + 4 * D * Hkv * dh + 2 * H * dh * D
            eff_ctx = ctx if not cfg.sliding_window else min(
                ctx, cfg.sliding_window)
            att = 4 * eff_ctx * H * dh            # scores + AV
            per_tok += att if decode else 0.5 * att
    elif cfg.mixer == "mamba2":
        di, N, P_ = cfg.d_inner_ssm, cfg.ssm_state, cfg.ssm_head_dim
        Hs_ = cfg.n_ssm_heads
        G = cfg.ssm_groups
        per_tok += 2 * D * (2 * di + 2 * G * N + Hs_) + 2 * di * D
        Q = 1 if decode else cfg.ssd_chunk
        per_tok += Hs_ * (2 * Q * N + 2 * Q * P_ + 4 * N * P_)
    elif cfg.mixer == "rwkv6":
        dh6 = 64
        H6 = D // dh6
        per_tok += 5 * 2 * D * D + 2 * D * (32 * 8 + 64 * 2)
        T = 1 if decode else cfg.rwkv_chunk
        per_tok += H6 * (5 * T * dh6 + 4 * dh6 * dh6)
    # ---- shared attention (zamba2)
    if cfg.shared_attn_every > 0:
        frac = cfg.attn_sites / L
        att_proj = 2 * D * H * dh + 4 * D * Hkv * dh + 2 * H * dh * D
        att_ctx = 4 * ctx * H * dh
        per_tok += frac * (att_proj + (att_ctx if decode else 0.5 * att_ctx))
    # ---- channel mixer
    if cfg.mlp == "swiglu":
        per_tok += 6 * D * F
    elif cfg.mlp == "gelu":
        per_tok += 4 * D * F
    elif cfg.mlp == "moe":
        Fe = cfg.d_ff_expert
        per_tok += 2 * D * cfg.n_experts
        per_tok += 6 * D * Fe * cfg.top_k * cfg.capacity_factor
        per_tok += 6 * D * Fe * cfg.n_shared_experts
    elif cfg.mlp == "rwkv6_cmix":
        per_tok += 2 * D * F * 2 + 2 * D * D
    # ---- cross attention (whisper decoder)
    enc_flops = 0.0
    if cfg.enc_dec:
        per_tok += 6 * D * D + 2 * D * D            # q,o + probs paths
        per_tok += 4 * cfg.enc_seq * H * dh
        enc_per_tok = (8 * D * D + 4 * cfg.enc_seq * H * dh * 0.5
                       + 4 * D * F)
        if not decode:   # encoder runs on train/prefill only
            enc_flops = (batch * cfg.enc_seq * enc_per_tok
                         * cfg.n_enc_layers)
        # cross-KV projection of encoder states (prefill)
        if not decode:
            enc_flops += batch * cfg.enc_seq * 4 * D * D * L

    total = tokens * per_tok * L + enc_flops
    total += tokens * 2 * D * V                     # logits
    if shape_kind == "train":
        total *= 3.0                                # fwd + bwd
    return total


def algo_hbm_bytes(cfg, shape_kind: str, seq: int, batch: int) -> float:
    """Analytic lower bound on HBM traffic per step (bytes, all chips)."""
    P_ = cfg.param_count()
    decode = shape_kind == "decode"
    tokens = batch * (1 if decode else seq)
    D, L = cfg.d_model, cfg.n_layers
    if shape_kind == "train":
        # params fp32 r/w + adam moments r/w + grads + bf16 cast reads
        par = P_ * (4 + 4 + 16 + 4 + 2)
        act = tokens * D * L * 12 * 2               # remat-era activations
        return par + act
    # inference: one pass over the (active) params (bf16 serving copy)
    # + cache traffic
    par = cfg.active_param_count() * 2
    if cfg.mixer == "attn":
        per_tok_cache = (2 * cfg.n_kv_heads * cfg.head_dim * 2
                         if not cfg.mla
                         else (cfg.kv_lora + cfg.qk_rope_dims) * 2)
        cache = batch * seq * per_tok_cache * L * (1 if decode else 1)
    else:
        cache = batch * L * 1e6 * 0  # state caches are negligible
        if cfg.shared_attn_every:
            cache = (batch * seq * 2 * cfg.n_kv_heads * cfg.head_dim * 2
                     * cfg.attn_sites)
    act = tokens * D * L * 8 * 2
    return par + cache + act


def model_flops_estimate(cfg, shape_kind: str, seq: int, batch: int) -> float:
    """Analytic 'useful' FLOPs: 6·N_active·D for train, 2·N_active·D for
    inference (+ attention score terms for full-attn archs)."""
    n_active = cfg.active_param_count()
    tokens = batch * seq if shape_kind in ("train", "prefill") else batch
    mult = 6.0 if shape_kind == "train" else 2.0
    base = mult * n_active * tokens
    # quadratic attention term (full-attn archs): 2·2·S²·D_attn per example
    if cfg.mixer == "attn":
        h_dim = cfg.n_heads * cfg.head_dim
        if shape_kind in ("train", "prefill"):
            att = 2 * 2 * seq * seq * h_dim * cfg.n_layers * batch
            att *= 3 if shape_kind == "train" else 1      # fwd+bwd
        else:
            att = 2 * 2 * seq * h_dim * cfg.n_layers * batch
        base += att
    return base


def delta_extrapolate(f_d1: float, f_d2: float, d1: int, d2: int,
                      L: int) -> float:
    """total(L) = f(d1) + (L-d1)/(d2-d1) · (f(d2)-f(d1)).

    Clamped non-negative and to at least max(f_d1, f_d2): compile-to-compile
    variance can make f(d2) < f(d1) (XLA folds a collective differently),
    and a negative slope extrapolated by L layers would go below zero.
    """
    if d2 == d1:
        return f_d1
    est = f_d1 + (L - d1) / (d2 - d1) * (f_d2 - f_d1)
    return max(est, f_d1, f_d2, 0.0)


def format_table(rows: list, keys: list) -> str:
    widths = {k: max(len(k), *(len(str(r.get(k, ""))) for r in rows))
              for k in keys}
    line = " | ".join(k.ljust(widths[k]) for k in keys)
    sep = "-+-".join("-" * widths[k] for k in keys)
    body = "\n".join(
        " | ".join(str(r.get(k, "")).ljust(widths[k]) for k in keys)
        for r in rows
    )
    return f"{line}\n{sep}\n{body}"
