"""Serving driver: batched generation with the ServingEngine.

    PYTHONPATH=src python -m repro.launch.serve \
        --arch smollm-360m --reduced --requests 8 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCHS, reduced as reduce_cfg
from repro.models import init_params
from repro.serve import ServingEngine, EngineConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m", choices=sorted(ARCHS))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    cfg = ARCHS[args.arch]
    if args.reduced:
        cfg = reduce_cfg(cfg)
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, EngineConfig(
        max_batch=args.max_batch,
        max_seq=args.prompt_len + args.max_new + 8,
        temperature=args.temperature,
    ))
    rng = np.random.default_rng(0)
    for uid in range(args.requests):
        L = args.prompt_len - (uid % 3) * 4      # mixed-length buckets
        eng.submit(uid, rng.integers(0, cfg.vocab, L), max_new=args.max_new)
    t0 = time.perf_counter()
    out = eng.run()
    dt = time.perf_counter() - t0
    tok = sum(len(v) for v in out.values())
    print(f"[serve] {len(out)} requests, {tok} tokens in {dt:.2f}s "
          f"({tok / dt:.1f} tok/s incl. compile)")
    for uid in sorted(out)[:3]:
        print(f"  req {uid}: {out[uid][:10]}...")
    return out


if __name__ == "__main__":
    main()
