"""Launch layer: mesh construction, dry-run, roofline, train/serve drivers.

NOTE: do not import dryrun from here — it must be executed as a fresh
process (it sets XLA_FLAGS before importing jax).
"""
from .mesh import make_production_mesh, make_host_mesh
