import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

This is the proof that the distribution config is coherent without real
hardware: ``.lower().compile()`` must succeed on the 16x16 (256-chip) pod
mesh and the 2x16x16 (512-chip) multi-pod mesh for every cell, and
``memory_analysis()`` must fit a TPU v5e (16 GB/chip).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun \
        [--arch NAME ...] [--shape NAME ...] [--mesh single|multi|both]
        [--delta] [--stkde] [--out results/dryrun]

Results are written incrementally (one JSON per cell) so the full matrix is
resumable; --skip-existing continues an interrupted run.
"""
import argparse
import json
import time
import traceback
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS
from repro.launch.mesh import make_production_mesh
from repro.launch import specs as specs_lib
from repro.launch import roofline as rl
from repro.distributed import sharding
from repro.models import model as model_lib
from repro.train import OptimizerConfig, optimizer as opt_lib
from repro.train.train_step import make_train_step

HBM_PER_CHIP = 16e9  # v5e


# ------------------------------------------------------------- cell builders
def build_train(cfg, mesh, shape):
    ocfg = OptimizerConfig(total_steps=10_000)
    step = make_train_step(cfg, ocfg)
    params_abs = specs_lib.param_specs_abstract(cfg)
    opt_abs = jax.eval_shape(opt_lib.init, params_abs)
    batch_abs = specs_lib.train_input_specs(cfg, shape)

    if cfg.train_parallelism == "fsdp":
        p_specs = sharding.fsdp_only_param_specs(params_abs, mesh)
        b_specs = sharding.data_specs(batch_abs, mesh, include_model=True)
    else:
        p_specs = sharding.param_specs(params_abs, mesh, fsdp=True)
        b_specs = sharding.data_specs(batch_abs, mesh)
    o_specs = opt_lib.OptState(
        mu=p_specs, nu=p_specs,
        step=jax.sharding.PartitionSpec(),
    )
    in_shardings = (
        sharding.make_sharding(p_specs, mesh),
        sharding.make_sharding(o_specs, mesh),
        sharding.make_sharding(b_specs, mesh),
    )

    def hinted(params, opt_state, batch):
        with sharding.hint_mesh(mesh):
            return step(params, opt_state, batch)

    fn = jax.jit(hinted, in_shardings=in_shardings)
    return fn, (params_abs, opt_abs, batch_abs)


def build_prefill(cfg, mesh, shape):
    params_abs = specs_lib.param_specs_abstract(cfg)
    inputs = specs_lib.prefill_input_specs(cfg, shape)
    fsdp = _serve_fsdp(cfg, mesh)
    p_specs = sharding.param_specs(params_abs, mesh, fsdp=fsdp)
    b_specs = sharding.data_specs(inputs, mesh)

    def fn(params, batch):
        kw = {k: v for k, v in batch.items() if k != "tokens"}
        with sharding.hint_mesh(mesh):
            return model_lib.prefill(cfg, params, batch["tokens"],
                                     max_seq=shape.seq_len, **kw)

    jitted = jax.jit(fn, in_shardings=(
        sharding.make_sharding(p_specs, mesh),
        sharding.make_sharding(b_specs, mesh),
    ))
    return jitted, (params_abs, inputs)


def build_decode(cfg, mesh, shape):
    params_abs = specs_lib.param_specs_abstract(cfg)
    # serving weights are bf16 (a dedicated inference copy — halves the
    # per-step HBM weight reads that dominate decode; §Perf extension)
    params_abs = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, jnp.bfloat16)
        if a.dtype == jnp.float32 and a.ndim >= 2 else a, params_abs)
    io = specs_lib.decode_input_specs(cfg, shape)
    fsdp = _serve_fsdp(cfg, mesh)
    p_specs = sharding.param_specs(params_abs, mesh, fsdp=fsdp)
    s_specs = sharding.decode_state_specs(cfg, io["state"], mesh)
    t_specs = sharding.data_specs({"t": io["token"]}, mesh)["t"]

    def fn(params, state, token):
        with sharding.hint_mesh(mesh):
            return model_lib.decode_step(cfg, params, token, state)

    jitted = jax.jit(fn, in_shardings=(
        sharding.make_sharding(p_specs, mesh),
        sharding.make_sharding(s_specs, mesh),
        jax.sharding.NamedSharding(mesh, t_specs),
    ))
    return jitted, (params_abs, io["state"], io["token"])


def _serve_fsdp(cfg, mesh) -> bool:
    """Serving shards params over data too when one TP shard won't fit."""
    tp = mesh.shape.get("model", 1)
    return cfg.param_count() * 4 / tp > 8e9


# ------------------------------------------------------------------- runner
def run_cell(arch: str, shape_name: str, mesh_kind: str, outdir: str,
             delta: bool = False, skip_existing: bool = False) -> dict:
    cfg = ARCHS[arch]
    shape = specs_lib.SHAPES[shape_name]
    tag = f"{mesh_kind}/{arch}__{shape_name}"
    path = os.path.join(outdir, mesh_kind, f"{arch}__{shape_name}.json")
    os.makedirs(os.path.dirname(path), exist_ok=True)
    if skip_existing and os.path.exists(path):
        with open(path) as f:
            return json.load(f)

    result = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
              "ok": False}
    ok, why = specs_lib.cell_applicable(cfg, shape)
    if not ok:
        result.update(skipped=True, reason=why, ok=True)
        _write(path, result)
        print(f"[dryrun] {tag}: SKIP ({why})")
        return result

    try:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
        chips = int(np.prod(list(mesh.shape.values())))
        t0 = time.perf_counter()
        fn, abstract = _build(cfg, mesh, shape)
        lowered = fn.lower(*abstract)
        t_lower = time.perf_counter() - t0
        t0 = time.perf_counter()
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis() or {}
        coll = rl.parse_collective_bytes(compiled.as_text())
        mem_d = {
            k: int(getattr(mem, k))
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes")
        }
        # argument/output sizes are per-device; temp is aggregated across
        # the forced host devices (empirically verified) -> normalize.
        n_dev = len(jax.devices())
        mem_d["temp_per_device"] = mem_d["temp_size_in_bytes"] // max(
            1, n_dev)
        total_dev_bytes = (mem_d["argument_size_in_bytes"]
                           + mem_d["temp_per_device"])
        result.update(
            ok=True,
            chips=chips,
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            memory=mem_d,
            fits_hbm=bool(total_dev_bytes < HBM_PER_CHIP),
            cost={"flops": float(cost.get("flops", 0.0)),
                  "bytes": _bytes_accessed(cost)},
            collectives=coll,
        )
        mf = rl.model_flops_estimate(cfg, shape.kind, shape.seq_len,
                                     shape.global_batch)
        result["model_flops"] = mf
        result["algo_flops"] = rl.algo_flops(
            cfg, shape.kind, shape.seq_len, shape.global_batch)
        result["algo_hbm_bytes"] = rl.algo_hbm_bytes(
            cfg, shape.kind, shape.seq_len, shape.global_batch)
        if delta:
            result["delta"] = _depth_delta(cfg, mesh, shape)
        _finalize_roofline(result, cfg, chips)
        print(f"[dryrun] {tag}: OK compile={t_compile:.1f}s "
              f"mem/dev={total_dev_bytes / 1e9:.2f}GB "
              f"coll/dev={coll['total'] / 1e9:.3f}GB")
    except Exception as e:
        result.update(error=f"{type(e).__name__}: {e}",
                      traceback=traceback.format_exc()[-4000:])
        print(f"[dryrun] {tag}: FAIL {type(e).__name__}: {e}")
    _write(path, result)
    return result


def _build(cfg, mesh, shape):
    if shape.kind == "train":
        return build_train(cfg, mesh, shape)
    if shape.kind == "prefill":
        return build_prefill(cfg, mesh, shape)
    return build_decode(cfg, mesh, shape)


def _bytes_accessed(cost: dict) -> float:
    return float(sum(v for k, v in cost.items()
                     if k.startswith("bytes accessed")))


def _depth_delta(cfg, mesh, shape) -> dict:
    """Compile unrolled shallow twins to correct scan-once cost counting."""
    p = max(1, cfg.shared_attn_every)
    r = cfg.first_dense_layers
    d1, d2 = r + p, r + 2 * p
    out = {}
    for d in (d1, d2):
        sub = cfg.replace(n_layers=d, scan_layers=False,
                          n_enc_layers=min(d, cfg.n_enc_layers)
                          if cfg.enc_dec else 0)
        fn, abstract = _build(sub, mesh, shape)
        compiled = fn.lower(*abstract).compile()
        cost = compiled.cost_analysis() or {}
        coll = rl.parse_collective_bytes(compiled.as_text())
        out[f"d{d}"] = {
            "flops": float(cost.get("flops", 0.0)),
            "bytes": _bytes_accessed(cost),
            "coll": coll["total"],
        }
    L = cfg.n_layers
    out["extrapolated"] = {
        k: rl.delta_extrapolate(out[f"d{d1}"][k], out[f"d{d2}"][k],
                                d1, d2, L)
        for k in ("flops", "bytes", "coll")
    }
    out["depths"] = [d1, d2]
    return out


def _finalize_roofline(result: dict, cfg, chips: int):
    """Three-term roofline.

    FLOPs / HBM bytes: analytic implemented-algorithm accounting (raw HLO
    numbers undercount while-loop bodies; the delta compiles correct the
    layer loop and are recorded for cross-checking, but inner chunk scans
    remain — see roofline.py docstring). Collectives: delta-corrected HLO
    parse when available, else raw (collectives live outside inner scans).
    """
    flops = result["algo_flops"]
    bts = result["algo_hbm_bytes"]
    if "delta" in result:
        coll = result["delta"]["extrapolated"]["coll"]
    else:
        coll = result["collectives"]["total"]
    roof = rl.Roofline(
        flops=flops, hbm_bytes=bts, coll_bytes_per_dev=coll, chips=chips,
        model_flops=result.get("model_flops", 0.0),
    )
    result["roofline"] = roof.to_dict()
    result["roofline_raw_hlo"] = rl.Roofline(
        flops=result["cost"]["flops"], hbm_bytes=result["cost"]["bytes"],
        coll_bytes_per_dev=result["collectives"]["total"], chips=chips,
        model_flops=result.get("model_flops", 0.0),
    ).to_dict()


# -------------------------------------------------------------- STKDE cells
def run_stkde_cell(instance_name: str, strategy: str, mesh_kind: str,
                   outdir: str, skip_existing: bool = False) -> dict:
    """Dry-run the paper's own technique at production scale."""
    from repro.core.datasets import INSTANCES
    from repro.distributed import stkde_dist as sd

    inst = INSTANCES[instance_name]
    dom = inst.domain()
    tag = f"{mesh_kind}/stkde_{strategy}_{instance_name}"
    path = os.path.join(outdir, mesh_kind,
                        f"stkde_{strategy}__{instance_name}.json")
    os.makedirs(os.path.dirname(path), exist_ok=True)
    if skip_existing and os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    result = {"arch": f"stkde-{strategy}", "shape": instance_name,
              "mesh": mesh_kind, "ok": False}
    try:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
        chips = int(np.prod(list(mesh.shape.values())))
        axes = ("data", "model")
        A, B = mesh.shape["data"], mesh.shape["model"]
        import math

        gx_loc = math.ceil(dom.Gx / A)
        gy_loc = math.ceil(dom.Gy / B)
        ntiles = A * B
        cap = max(8, int(np.ceil(4.0 * inst.n / ntiles / 8)) * 8)
        if strategy == "pd_xyt":
            if mesh_kind != "multi":
                result.update(skipped=True, ok=True,
                              reason="3-axis decomposition needs the "
                              "multi-pod mesh")
                _write(path, result)
                return result
            ax3 = ("pod", "data", "model")
            R = mesh.shape["pod"]
            fn = sd.build_pd_xyt(dom, mesh, ax3, inst.n)
            bp = jax.ShapeDtypeStruct((R, A, B, cap, 3), jnp.float32)
            bv = jax.ShapeDtypeStruct((R, A, B, cap), jnp.float32)
            abstract = (bp, bv)
        elif strategy in ("pd", "pd_xt"):
            rep = "pod" if mesh_kind == "multi" else None
            builder = sd.build_pd_xt if strategy == "pd_xt" else sd.build_pd
            fn = builder(dom, mesh, axes, inst.n, rep_axis=rep)
            lead = (mesh.shape["pod"],) if rep else ()
            bp = jax.ShapeDtypeStruct(lead + (A, B, cap, 3), jnp.float32)
            bv = jax.ShapeDtypeStruct(lead + (A, B, cap), jnp.float32)
            abstract = (bp, bv)
        elif strategy == "dd":
            fn = sd.build_dd(dom, mesh, axes, inst.n)
            bp = jax.ShapeDtypeStruct((A, B, cap, 3), jnp.float32)
            bv = jax.ShapeDtypeStruct((A, B, cap), jnp.float32)
            abstract = (bp, bv)
        else:  # dr
            npad = int(np.ceil(inst.n / chips)) * chips
            fn = sd.build_dr(dom, mesh, axes, inst.n)
            abstract = (jax.ShapeDtypeStruct((npad, 3), jnp.float32),)
        t0 = time.perf_counter()
        compiled = fn.lower(*abstract).compile()
        t_compile = time.perf_counter() - t0
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis() or {}
        coll = rl.parse_collective_bytes(compiled.as_text())
        total_dev = int(mem.argument_size_in_bytes + mem.temp_size_in_bytes)
        result.update(
            ok=True, chips=chips, compile_s=round(t_compile, 2),
            memory={"argument_size_in_bytes": int(
                mem.argument_size_in_bytes),
                "temp_size_in_bytes": int(mem.temp_size_in_bytes)},
            fits_hbm=bool(total_dev < HBM_PER_CHIP),
            cost={"flops": float(cost.get("flops", 0.0)),
                  "bytes": _bytes_accessed(cost)},
            collectives=coll,
            grid_voxels=dom.grid_voxels, n_points=inst.n, cap=cap,
        )
        roof = rl.Roofline(
            flops=result["cost"]["flops"], hbm_bytes=result["cost"]["bytes"],
            coll_bytes_per_dev=coll["total"], chips=chips,
            model_flops=2.0 * inst.n * dom.cylinder_voxels,
        )
        result["roofline"] = roof.to_dict()
        print(f"[dryrun] {tag}: OK compile={t_compile:.1f}s "
              f"mem/dev={total_dev / 1e9:.2f}GB")
    except Exception as e:
        result.update(error=f"{type(e).__name__}: {e}",
                      traceback=traceback.format_exc()[-4000:])
        print(f"[dryrun] {tag}: FAIL {type(e).__name__}: {e}")
    _write(path, result)
    return result


def _write(path, obj):
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(obj, f, indent=1, default=float)
    os.replace(tmp, path)


STKDE_DRYRUN_INSTANCES = ["eBird_Hr-Hb", "eBird_Lr-Hb", "Flu_Hr-Hb",
                          "PollenUS_VHr-Lb"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", nargs="*", default=sorted(ARCHS))
    ap.add_argument("--shape", nargs="*", default=list(specs_lib.SHAPES))
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--delta", action="store_true",
                    help="depth-delta scan-cost correction (extra compiles)")
    ap.add_argument("--stkde", action="store_true",
                    help="also dry-run STKDE strategies at production scale")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    meshes = (["single", "multi"] if args.mesh == "both" else [args.mesh])
    failures = []
    for mesh_kind in meshes:
        for arch in args.arch:
            for shape in args.shape:
                r = run_cell(arch, shape, mesh_kind, args.out,
                             delta=args.delta and mesh_kind == "single",
                             skip_existing=args.skip_existing)
                if not r.get("ok"):
                    failures.append((mesh_kind, arch, shape))
        if args.stkde:
            for inst in STKDE_DRYRUN_INSTANCES:
                strats = ("pd", "pd_xt", "dd") if mesh_kind == "single" \
                    else ("pd", "pd_xt", "pd_xyt", "dd")
                for strat in strats:
                    r = run_stkde_cell(inst, strat, mesh_kind, args.out,
                                       skip_existing=args.skip_existing)
                    if not r.get("ok"):
                        failures.append((mesh_kind, f"stkde-{strat}", inst))
    print(f"\n[dryrun] done; {len(failures)} failures")
    for f in failures:
        print("  FAIL:", f)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
