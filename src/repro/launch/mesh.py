"""Production mesh construction (pure function — importing this module never
touches jax device state)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips).

    Axes: pod = DCN data parallelism; data = ICI batch/FSDP; model = ICI TP.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
    )


def make_host_mesh(n_devices: int = 8, multi_pod: bool = False):
    """Small-mesh twin for CPU tests (same axis names / code paths)."""
    if multi_pod:
        shape = (2, max(1, n_devices // 4), 2)
        axes = ("pod", "data", "model")
    else:
        shape = (max(1, n_devices // 2), 2)
        axes = ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
    )
