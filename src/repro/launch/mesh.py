"""Production mesh construction (pure function — importing this module never
touches jax device state) plus failure-driven mesh shrinking."""
from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips).

    Axes: pod = DCN data parallelism; data = ICI batch/FSDP; model = ICI TP.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
    )


def shrink_mesh(mesh, n_lost: int = 1):
    """Rebuild ``mesh`` after losing ``n_lost`` devices (tail devices are
    dropped — the injector does not name a victim, and any survivor
    permutation is equivalent for our collectives).

    Axis names are preserved so strategy code keeps working unchanged.
    The trailing (model) axis size is kept where possible and halved
    until the survivors fill at least one full row; leading extra axes
    (e.g. ``pod``) collapse to 1. Returns ``None`` when fewer than two
    usable devices remain — the caller then degrades to single-device
    execution.
    """
    devices = list(np.asarray(mesh.devices).reshape(-1))
    survivors = devices[: len(devices) - n_lost]
    names = tuple(mesh.axis_names)
    last = int(mesh.shape[names[-1]]) if len(names) > 1 else 1
    n = len(survivors)
    while last > 1 and n // last < 1:
        last //= 2
    lead = n // max(1, last)
    used = lead * last
    if used < 2:
        return None
    if len(names) == 1:
        shape = (used,)
    else:
        shape = (1,) * (len(names) - 2) + (lead, last)
    arr = np.asarray(survivors[:used]).reshape(shape)
    return jax.sharding.Mesh(arr, names)


def make_host_mesh(n_devices: int = 8, multi_pod: bool = False):
    """Small-mesh twin for CPU tests (same axis names / code paths)."""
    if multi_pod:
        shape = (2, max(1, n_devices // 4), 2)
        axes = ("pod", "data", "model")
    else:
        shape = (max(1, n_devices // 2), 2)
        axes = ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
    )
