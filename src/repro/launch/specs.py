"""ShapeDtypeStruct stand-ins for every (arch × shape) dry-run cell.

``input_specs(cfg, shape_name)`` returns abstract inputs only — weak-type
correct, shardable, zero device allocation (the shannon/kernels pattern).

Shape cells (LM transformers): train_4k / prefill_32k / decode_32k /
long_500k — see SHAPES. ``decode_*`` / ``long_*`` lower ``serve_step``
(one token against a seq_len cache), not ``train_step``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import model as model_lib


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeCell("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524288, 1),
}


def cell_applicable(cfg, shape: ShapeCell) -> Tuple[bool, str]:
    """long_500k only for sub-quadratic archs (DESIGN.md §5)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, (
            "full-attention arch: 500k-context decode is skipped per "
            "assignment note (sub-quadratic archs only)"
        )
    return True, ""


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_input_specs(cfg, shape: ShapeCell) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    extra = {}
    s_text = S
    if cfg.frontend == "vision":
        s_text = S - cfg.n_vision_tokens
        extra["vision_embeds"] = _sds(
            (B, cfg.n_vision_tokens, cfg.d_model), jnp.bfloat16
        )
    if cfg.enc_dec:
        extra["audio_frames"] = _sds(
            (B, cfg.enc_seq, cfg.d_model), jnp.bfloat16
        )
    return {
        "tokens": _sds((B, s_text), jnp.int32),
        "labels": _sds((B, s_text), jnp.int32),
        **extra,
    }


def prefill_input_specs(cfg, shape: ShapeCell) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    out = {"tokens": _sds((B, S), jnp.int32)}
    if cfg.frontend == "vision":
        out["tokens"] = _sds((B, S - cfg.n_vision_tokens), jnp.int32)
        out["vision_embeds"] = _sds(
            (B, cfg.n_vision_tokens, cfg.d_model), jnp.bfloat16
        )
    if cfg.enc_dec:
        out["audio_frames"] = _sds(
            (B, cfg.enc_seq, cfg.d_model), jnp.bfloat16
        )
    return out


def decode_input_specs(cfg, shape: ShapeCell) -> Dict[str, Any]:
    """Token + DecodeState stand-ins (cache sized seq_len)."""
    B, S = shape.global_batch, shape.seq_len
    state = jax.eval_shape(
        lambda: model_lib.init_decode_state(cfg, B, S, jnp.bfloat16)
    )
    return {"token": _sds((B, 1), jnp.int32), "state": state}


def param_specs_abstract(cfg, key=None):
    """Abstract param tree via eval_shape (no allocation)."""
    import functools

    k = jax.random.PRNGKey(0)
    return jax.eval_shape(
        functools.partial(model_lib.init_params, cfg), k
    )
