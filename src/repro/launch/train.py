"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train \
        --arch smollm-360m --reduced --steps 200 --batch 16 --seq 128 \
        --ckpt-dir /tmp/run1

Runs on whatever devices exist (1 CPU locally; a pod when launched under
multi-host JAX). Mesh: (data, model) over available devices; params sharded
by distributed/sharding.py rules; fault tolerance via train.runner.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, reduced as reduce_cfg
from repro.data import DataConfig, SyntheticLM
from repro.distributed import sharding
from repro.models import init_params
from repro.train import (
    OptimizerConfig, RunnerConfig, TrainRunner, make_train_step,
    optimizer as opt_lib,
)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m", choices=sorted(ARCHS))
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-sized same-family config")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--model-axis", type=int, default=1)
    args = ap.parse_args(argv)

    cfg = ARCHS[args.arch]
    if args.reduced:
        cfg = reduce_cfg(cfg)
    n_dev = len(jax.devices())
    model_size = min(args.model_axis, n_dev)
    mesh = jax.make_mesh(
        (n_dev // model_size, model_size), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )
    print(f"[train] arch={cfg.name} devices={n_dev} "
          f"mesh={dict(mesh.shape)} params~{cfg.param_count()/1e6:.1f}M")

    params = init_params(cfg, jax.random.PRNGKey(0))
    p_specs = sharding.param_specs(params, mesh, fsdp=True)
    params = jax.device_put(params, sharding.make_sharding(p_specs, mesh))
    opt_state = opt_lib.init(params)

    ocfg = OptimizerConfig(lr=args.lr, warmup_steps=max(10, args.steps // 20),
                           total_steps=args.steps)
    step_fn = jax.jit(make_train_step(cfg, ocfg))

    data = SyntheticLM(DataConfig(
        vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch,
    ))
    b_spec = sharding.make_sharding(
        sharding.data_specs(data.batch_at(0), mesh), mesh)

    rcfg = RunnerConfig(ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                        max_steps=args.steps)
    runner = TrainRunner(rcfg, step_fn, params, opt_state)
    runner.install_preemption_hook()

    def batches():
        s = runner.step
        while True:
            b = data.batch_at(s)
            yield jax.device_put(
                {k: jnp.asarray(v) for k, v in b.items()}, b_spec)
            s += 1

    summary = runner.run(batches())
    print(f"[train] done: {summary}")
    hist = runner.metrics_history
    if hist:
        print(f"[train] loss {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f}"
              f" over {len(hist)} steps")
    return summary


if __name__ == "__main__":
    main()
