"""Point-based STKDE algorithms: PB, PB-DISK, PB-BAR, PB-SYM.

Algorithm 2/3 of the paper: stream over points, each point scatter-adds its
bandwidth cylinder into the grid. The four variants differ in how much of the
kernel evaluation is hoisted out of the cylinder loop:

  PB       evaluates ks*kt per cylinder voxel              (no hoisting)
  PB-DISK  hoists the spatial invariant Ks[X,Y]            (Algorithm 3, half)
  PB-BAR   hoists the temporal invariant Kt[T]
  PB-SYM   hoists both; cylinder work is a pure outer product Ks ⊗ Kt

All variants produce identical grids; they exist separately so the Table-3
benchmark reproduces the paper's flop-reduction story. The redundant work in
PB / PB-DISK / PB-BAR is expressed through *materialized* broadcasts so XLA
actually performs it.

This module is the readable reference & CPU execution path; the TPU
performance path is ``repro.kernels`` (tile GEMM). Both are cross-tested.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro import compat

from .geometry import Domain
from . import kernels_math as km

VARIANTS = ("pb", "disk", "bar", "sym")


def _cylinder_values(
    pts: jnp.ndarray,  # (B, 3)
    vox: jnp.ndarray,  # (B, 3) int32 home voxels
    dom: Domain,
    variant: str,
    ks: km.SpatialKernel,
    kt: km.TemporalKernel,
    n_total: int,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Kernel values + linear indices for a block of points.

    Returns (lin_idx, vals), both (B, Dx*Dy*Dt). Out-of-grid voxels get
    lin_idx == grid_size (dropped by the scatter's mode='drop').
    """
    Hs, Ht = dom.Hs, dom.Ht
    Dx = Dy = 2 * Hs + 1
    Dt = 2 * Ht + 1
    B = pts.shape[0]
    Gx, Gy, Gt = dom.grid_shape
    gsz = Gx * Gy * Gt

    dx = jnp.arange(-Hs, Hs + 1)
    dt = jnp.arange(-Ht, Ht + 1)
    X = vox[:, 0:1] + dx[None, :]                    # (B, Dx)
    Y = vox[:, 1:2] + dx[None, :]                    # (B, Dy)
    T = vox[:, 2:3] + dt[None, :]                    # (B, Dt)

    # voxel-center coordinates of the cylinder bbox
    xc = dom.ox + (X.astype(jnp.float32) + 0.5) * dom.sres
    yc = dom.oy + (Y.astype(jnp.float32) + 0.5) * dom.sres
    tc = dom.ot + (T.astype(jnp.float32) + 0.5) * dom.tres
    u = (xc - pts[:, 0:1]) / dom.hs                  # (B, Dx)
    v = (yc - pts[:, 1:2]) / dom.hs                  # (B, Dy)
    w = (tc - pts[:, 2:3]) / dom.ht                  # (B, Dt)

    norm = km.normalization(n_total, dom.hs, dom.ht)
    shape3 = (B, Dx, Dy, Dt)

    def _pin(x):
        """Materialize a broadcast for real.

        XLA sinks broadcasts through elementwise chains — i.e. the compiler
        performs the paper's DISK/BAR/SYM hoisting automatically, which
        would make all four variants compile to the same program. The
        barrier pins the broadcast so each variant performs the flops the
        scalar algorithm it models would perform (Table-3 benchmark
        fidelity; results are bit-identical either way).
        """
        return jax.lax.optimization_barrier(x)

    if variant == "sym":
        Ks = ks(u[:, :, None], v[:, None, :]) * norm         # (B, Dx, Dy)
        Kt = kt(w)                                           # (B, Dt)
        vals = Ks[:, :, :, None] * Kt[:, None, None, :]
    elif variant == "disk":
        Ks = ks(u[:, :, None], v[:, None, :]) * norm
        W = _pin(jnp.broadcast_to(w[:, None, None, :], shape3))
        vals = Ks[:, :, :, None] * kt(W)
    elif variant == "bar":
        Kt = kt(w) * norm
        U = _pin(jnp.broadcast_to(u[:, :, None, None], shape3))
        V = _pin(jnp.broadcast_to(v[:, None, :, None], shape3))
        vals = ks(U, V) * Kt[:, None, None, :]
    elif variant == "pb":
        U = _pin(jnp.broadcast_to(u[:, :, None, None], shape3))
        V = _pin(jnp.broadcast_to(v[:, None, :, None], shape3))
        W = _pin(jnp.broadcast_to(w[:, None, None, :], shape3))
        vals = ks(U, V) * kt(W) * norm
    else:
        raise ValueError(f"unknown variant {variant!r}")

    # linear indices with out-of-bounds -> gsz (dropped)
    okx = (X >= 0) & (X < Gx)
    oky = (Y >= 0) & (Y < Gy)
    okt = (T >= 0) & (T < Gt)
    px = jnp.where(okx, X * (Gy * Gt), gsz)
    py = jnp.where(oky, Y * Gt, gsz)
    ptt = jnp.where(okt, T, gsz)
    lin = (
        px[:, :, None, None] + py[:, None, :, None] + ptt[:, None, None, :]
    )
    lin = jnp.minimum(lin, gsz)                      # keep within drop range
    return lin.reshape(B, -1), vals.reshape(B, -1)


def _block_size(dom: Domain, budget_elems: int) -> int:
    per_point = dom.cylinder_voxels
    return max(1, min(4096, budget_elems // max(1, per_point)))


@functools.partial(
    jax.jit,
    static_argnames=(
        "dom", "variant", "ks", "kt", "budget_elems", "n_total"
    ),
)
def _pb_impl(
    points: jnp.ndarray,
    dom: Domain,
    variant: str,
    ks,
    kt,
    budget_elems: int,
    n_total: int = None,
) -> jnp.ndarray:
    n = points.shape[0]
    n_norm = n if n_total is None else n_total
    gsz = dom.grid_voxels
    if gsz >= 2**30:
        raise ValueError(
            "scatter-path PB needs grid < 2^30 voxels; use the tiled kernel "
            "or the distributed strategies for larger grids"
        )
    B = _block_size(dom, budget_elems)
    nblocks = -(-n // B)
    pad = nblocks * B - n
    pts = jnp.pad(points.astype(jnp.float32), ((0, pad), (0, 0)))
    # padded points are parked outside every grid cylinder via a huge coord
    if pad:
        far = jnp.float32(dom.ox - 1e8)
        pts = pts.at[n:, 0].set(far)
    # Unclipped home voxels: points outside this (possibly local) domain
    # still contribute the in-domain part of their cylinder; fully
    # out-of-reach voxels are dropped by the scatter.
    vox = dom.point_voxels_unclipped(pts)
    pts_b = pts.reshape(nblocks, B, 3)
    vox_b = vox.reshape(nblocks, B, 3)

    grid = jnp.zeros((gsz + 1,), dtype=jnp.float32)  # +1 slot absorbs drops
    # Inside shard_map the scan carry must carry the same varying-manual-axes
    # tag as the point shards feeding it.
    vma = compat.vma_of(points)
    if vma:
        grid = compat.pcast(grid, tuple(vma), to="varying")

    def body(grid, blk):
        p, v = blk
        lin, vals = _cylinder_values(p, v, dom, variant, ks, kt, n_norm)
        return grid.at[lin.reshape(-1)].add(
            vals.reshape(-1), mode="drop"
        ), None

    grid, _ = jax.lax.scan(body, grid, (pts_b, vox_b))
    return grid[:gsz].reshape(dom.grid_shape)


@functools.partial(
    jax.jit,
    static_argnames=("dom", "variant", "ks", "kt", "budget_elems",
                     "n_total"),
)
def _pb_eval_impl(points, dom, variant, ks, kt, budget_elems,
                  n_total=None):
    """Kernel-evaluation phase only (no scatter): checksum of all cylinder
    values. Times the compute phase the paper's Table 3 differentiates;
    the scatter/accumulate phase is variant-independent (see benchmarks)."""
    n = points.shape[0]
    n_norm = n if n_total is None else n_total
    B = _block_size(dom, budget_elems)
    nblocks = -(-n // B)
    pad = nblocks * B - n
    pts = jnp.pad(points.astype(jnp.float32), ((0, pad), (0, 0)))
    if pad:
        pts = pts.at[n:, 0].set(jnp.float32(dom.ox - 1e8))
    vox = dom.point_voxels_unclipped(pts)

    def body(acc, blk):
        p, v = blk
        _, vals = _cylinder_values(p, v, dom, variant, ks, kt, n_norm)
        return acc + vals.sum(), None

    acc, _ = jax.lax.scan(
        body, jnp.float32(0),
        (pts.reshape(nblocks, B, 3), vox.reshape(nblocks, B, 3)),
    )
    return acc


def pb_eval_only(points, dom: Domain, variant: str = "sym",
                 ks: km.SpatialKernel = km.DEFAULT_KS,
                 kt: km.TemporalKernel = km.DEFAULT_KT,
                 budget_elems: int = 1 << 22):
    if variant not in VARIANTS:
        raise ValueError(f"variant must be one of {VARIANTS}")
    return _pb_eval_impl(jnp.asarray(points), dom, variant, ks, kt,
                         budget_elems)


def pb(points, dom: Domain, variant: str = "sym",
       ks: km.SpatialKernel = km.DEFAULT_KS,
       kt: km.TemporalKernel = km.DEFAULT_KT,
       budget_elems: int = 1 << 22,
       n_total: int = None) -> jnp.ndarray:
    """Point-based STKDE. ``variant`` in {"pb", "disk", "bar", "sym"}.

    ``n_total`` overrides the normalization count (distributed callers pass
    the global point count while supplying only their local shard).
    """
    if variant not in VARIANTS:
        raise ValueError(f"variant must be one of {VARIANTS}")
    return _pb_impl(
        jnp.asarray(points), dom, variant, ks, kt, budget_elems, n_total
    )


def pb_sym(points, dom: Domain, **kw) -> jnp.ndarray:
    return pb(points, dom, variant="sym", **kw)
