"""Point -> tile bucketing (host-side data preparation).

The TPU-native STKDE paths (Pallas tile kernel, DD/PD shard_map strategies,
VB-DEC) all consume *capacity-padded dense buckets*: a (ntx, nty, ntt, cap, 3)
array of points plus a validity mask. Scatter becomes dense per-tile compute.

Two bucketing modes:
  * ``home``    — each point appears exactly once, in the tile containing its
                  voxel (work-efficient; used by PD / owner-computes).
  * ``overlap`` — each point appears in every tile its bandwidth cylinder's
                  bounding box intersects (DD-style replication; makes each
                  tile self-contained at the cost of cut-cylinder work
                  overhead — the exact overhead the paper measures in Fig. 9).

This preparation is host-side numpy by design: in production it runs in the
per-host data pipeline (like tokenization), not on the accelerator.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import numpy as np

from repro.obs import trace as obs_trace

from .geometry import Domain


@dataclasses.dataclass
class Buckets:
    points: np.ndarray  # (ntx, nty, ntt, cap, 3) float32
    valid: np.ndarray   # (ntx, nty, ntt, cap) bool
    counts: np.ndarray  # (ntx, nty, ntt) int64 — true per-tile loads
    tile: Tuple[int, int, int]
    cap: int
    mode: str

    @property
    def ntiles(self) -> Tuple[int, int, int]:
        return self.points.shape[:3]

    @property
    def replication_factor(self) -> float:
        """Average copies per point (1.0 for home; >1 measures DD overhead)."""
        total = int(self.counts.sum())
        return total / max(1, self._n_source)

    _n_source: int = 1


def round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def default_tile(dom: Domain) -> Tuple[int, int, int]:
    """A tile at least as large as the bandwidth cylinder bbox, 8-aligned."""
    bx = min(round_up(dom.Gx, 8), round_up(2 * dom.Hs + 1, 8))
    by = min(round_up(dom.Gy, 8), round_up(2 * dom.Hs + 1, 8))
    bt = min(round_up(dom.Gt, 4), round_up(2 * dom.Ht + 1, 4))
    return (bx, by, bt)


def num_tiles(dom: Domain, tile: Tuple[int, int, int]) -> Tuple[int, int, int]:
    bx, by, bt = tile
    return (
        math.ceil(dom.Gx / bx),
        math.ceil(dom.Gy / by),
        math.ceil(dom.Gt / bt),
    )


def _point_voxels_np(pts: np.ndarray, dom: Domain) -> np.ndarray:
    idx = np.floor(
        (pts - np.array([dom.ox, dom.oy, dom.ot]))
        / np.array([dom.sres, dom.sres, dom.tres])
    ).astype(np.int64)
    hi = np.array([dom.Gx - 1, dom.Gy - 1, dom.Gt - 1])
    return np.clip(idx, 0, hi)


def _densify(
    tile_ids: np.ndarray,
    pts_rep: np.ndarray,
    nt: Tuple[int, int, int],
    cap: Optional[int],
    n_source: int,
    tile: Tuple[int, int, int],
    mode: str,
) -> Buckets:
    """Build the capacity-padded dense layout from (point copy -> tile id)."""
    ntx, nty, ntt = nt
    ntiles_flat = ntx * nty * ntt
    counts = np.bincount(tile_ids, minlength=ntiles_flat)
    true_cap = int(counts.max()) if counts.size else 0
    if cap is None:
        cap = max(8, round_up(max(true_cap, 1), 8))
    elif true_cap > cap:
        raise ValueError(
            f"bucket capacity {cap} < max tile load {true_cap}; "
            "raise cap or use a finer decomposition"
        )
    order = np.argsort(tile_ids, kind="stable")
    sorted_ids = tile_ids[order]
    sorted_pts = pts_rep[order]
    # position of each copy within its bucket
    starts = np.zeros(ntiles_flat + 1, dtype=np.int64)
    np.cumsum(counts, out=starts[1:])
    within = np.arange(len(sorted_ids)) - starts[sorted_ids]

    points = np.zeros((ntiles_flat, cap, 3), dtype=np.float32)
    valid = np.zeros((ntiles_flat, cap), dtype=bool)
    points[sorted_ids, within] = sorted_pts
    valid[sorted_ids, within] = True

    b = Buckets(
        points=points.reshape(ntx, nty, ntt, cap, 3),
        valid=valid.reshape(ntx, nty, ntt, cap),
        counts=counts.reshape(ntx, nty, ntt),
        tile=tile,
        cap=cap,
        mode=mode,
    )
    b._n_source = n_source
    return b


def bucket_points_home(
    pts: np.ndarray,
    dom: Domain,
    tile: Tuple[int, int, int],
    cap: Optional[int] = None,
) -> Buckets:
    """Each point assigned once, to the tile containing its voxel."""
    pts = np.asarray(pts, dtype=np.float32)
    nt = num_tiles(dom, tile)
    with obs_trace.span("bucketing.home", n=len(pts),
                        tiles=f"{nt[0]}x{nt[1]}x{nt[2]}") as sp:
        vox = _point_voxels_np(pts, dom)
        tx = vox[:, 0] // tile[0]
        ty = vox[:, 1] // tile[1]
        tt = vox[:, 2] // tile[2]
        ids = (tx * nt[1] + ty) * nt[2] + tt
        b = _densify(ids, pts, nt, cap, len(pts), tile, "home")
        sp.set(cap=b.cap)
        return b


def bucket_points_overlap(
    pts: np.ndarray,
    dom: Domain,
    tile: Tuple[int, int, int],
    cap: Optional[int] = None,
) -> Buckets:
    """Each point assigned to every tile its cylinder bbox intersects."""
    pts = np.asarray(pts, dtype=np.float32)
    n = len(pts)
    nt = num_tiles(dom, tile)
    with obs_trace.span("bucketing.overlap", n=n,
                        tiles=f"{nt[0]}x{nt[1]}x{nt[2]}") as sp:
        b = _bucket_overlap(pts, dom, tile, nt, cap, n)
        sp.set(cap=b.cap, replication=round(b.replication_factor, 3))
        return b


def _bucket_overlap(pts, dom, tile, nt, cap, n) -> Buckets:
    vox = _point_voxels_np(pts, dom)
    lo = np.empty((n, 3), dtype=np.int64)
    hi = np.empty((n, 3), dtype=np.int64)
    H = np.array([dom.Hs, dom.Hs, dom.Ht])
    B = np.array(tile)
    NT = np.array(nt)
    lo[:] = np.clip((vox - H) // B, 0, NT - 1)
    hi[:] = np.clip((vox + H) // B, 0, NT - 1)
    span = hi - lo + 1                       # (n, 3)
    smax = span.max(axis=0)                  # max span per dim

    # enumerate all (ox, oy, ot) offsets up to smax and mask invalid ones
    offs = np.stack(
        np.meshgrid(
            np.arange(smax[0]), np.arange(smax[1]), np.arange(smax[2]),
            indexing="ij",
        ),
        axis=-1,
    ).reshape(-1, 3)                          # (S, 3)
    tids = lo[:, None, :] + offs[None, :, :]  # (n, S, 3)
    ok = (offs[None, :, :] < span[:, None, :]).all(axis=-1)  # (n, S)
    flat = (tids[..., 0] * nt[1] + tids[..., 1]) * nt[2] + tids[..., 2]
    sel = ok.reshape(-1)
    ids = flat.reshape(-1)[sel]
    pts_rep = np.broadcast_to(pts[:, None, :], tids.shape).reshape(-1, 3)[sel]
    return _densify(ids, pts_rep, nt, cap, n, tile, "overlap")
