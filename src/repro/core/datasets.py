"""Synthetic datasets matching the paper's four real-world datasets.

The paper's data (Dengue surveillance, PollenUS tweets, avian Flu records,
eBird sightings) is not redistributable; we generate clustered spatiotemporal
point processes with the same instance parameters (n, grid, bandwidths —
paper Table 2). Cluster structure matters: the paper's load-imbalance story
(PD-SCHED/REP) only exists because real events cluster; our generator mixes
dense Gaussian clusters with a uniform background and a seasonal temporal
cycle to reproduce that skew.

Table-2 cells that are garbled in the source text are reconstructed from the
paper's own consistency relations (resolution doubling doubles H; runtimes
scale with Hs^2*Ht) and flagged ``approx=True``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple

import numpy as np

from .geometry import Domain


@dataclasses.dataclass(frozen=True)
class STKDEInstance:
    name: str
    n: int
    Gx: int
    Gy: int
    Gt: int
    Hs: int
    Ht: int
    clusters: int = 24
    cluster_frac: float = 0.8
    seed: int = 0
    approx: bool = False  # True where Table 2 was OCR-garbled

    # ------------------------------------------------------------------ api
    def domain(self) -> Domain:
        """Unit-resolution domain: voxel == domain unit, hs == Hs exactly."""
        return Domain(
            gx=float(self.Gx), gy=float(self.Gy), gt=float(self.Gt),
            sres=1.0, tres=1.0, hs=float(self.Hs), ht=float(self.Ht),
        )

    def points(self, n: Optional[int] = None) -> np.ndarray:
        n = self.n if n is None else min(n, self.n)
        return clustered_events(
            n, self.domain(), seed=self.seed, n_clusters=self.clusters,
            cluster_frac=self.cluster_frac,
        )

    def scaled(self, max_voxels: int = 2_000_000,
               max_points: int = 50_000) -> "STKDEInstance":
        """Shrink grid/points for CPU benchmarking, keeping the work profile.

        Bandwidths (in voxels) are preserved so the per-point cylinder cost —
        the quantity the paper's algorithms differ on — is unchanged; grid
        dims shrink isotropically, clamped to hold at least one cylinder.
        """
        vox = self.Gx * self.Gy * self.Gt
        f = min(1.0, (max_voxels / vox) ** (1.0 / 3.0))
        gx = max(2 * self.Hs + 2, int(self.Gx * f))
        gy = max(2 * self.Hs + 2, int(self.Gy * f))
        gt = max(2 * self.Ht + 2, int(self.Gt * f))
        return dataclasses.replace(
            self, n=min(self.n, max_points), Gx=gx, Gy=gy, Gt=gt,
            name=self.name + "_scaled",
        )

    @property
    def grid_mbytes(self) -> float:
        return self.Gx * self.Gy * self.Gt * 4 / 2**20


def clustered_events(
    n: int,
    dom: Domain,
    seed: int = 0,
    n_clusters: int = 24,
    cluster_frac: float = 0.8,
) -> np.ndarray:
    """Clustered space-time point process inside the domain box."""
    rng = np.random.default_rng(seed)
    n_c = int(n * cluster_frac)
    n_bg = n - n_c
    lo = np.array([dom.ox, dom.oy, dom.ot])
    span = np.array([dom.gx, dom.gy, dom.gt])

    centers = lo + rng.random((n_clusters, 3)) * span
    # Zipf-ish cluster sizes: a few clusters dominate (drives load imbalance)
    w = 1.0 / np.arange(1, n_clusters + 1)
    w /= w.sum()
    sizes = rng.multinomial(n_c, w)
    sigma_s = max(dom.gx, dom.gy) / 40.0
    sigma_t = dom.gt / 30.0

    parts = []
    for c, s in zip(centers, sizes):
        if s == 0:
            continue
        p = np.empty((s, 3))
        p[:, 0] = rng.normal(c[0], sigma_s, s)
        p[:, 1] = rng.normal(c[1], sigma_s, s)
        # seasonal: cluster time + weekly-ish harmonics
        p[:, 2] = c[2] + sigma_t * np.sin(rng.normal(0, 1.2, s)) + rng.normal(
            0, sigma_t / 3, s
        )
        parts.append(p)
    if n_bg:
        parts.append(lo + rng.random((n_bg, 3)) * span)
    pts = np.concatenate(parts, axis=0)[:n]
    eps = 1e-3
    hi = lo + span * (1 - eps)
    return np.clip(pts, lo, hi).astype(np.float32)


# --------------------------------------------------------------------------
# Paper Table 2 — 21 instances. approx=True marks reconstructed cells.
# --------------------------------------------------------------------------
_T = STKDEInstance
INSTANCES: Dict[str, STKDEInstance] = {
    i.name: i
    for i in [
        _T("Dengue_Lr-Lb", 11056, 148, 194, 728, 3, 1, seed=1),
        _T("Dengue_Lr-Hb", 11056, 148, 194, 728, 25, 1, seed=1),
        _T("Dengue_Hr-Lb", 11056, 294, 386, 728, 6, 1, seed=1, approx=True),
        _T("Dengue_Hr-Hb", 11056, 294, 386, 728, 50, 1, seed=1, approx=True),
        _T("Dengue_Hr-VHb", 11056, 294, 386, 728, 50, 14, seed=1),
        _T("PollenUS_Lr-Lb", 588189, 131, 61, 84, 2, 3, seed=2),
        _T("PollenUS_Hr-Lb", 588189, 651, 301, 84, 10, 3, seed=2),
        _T("PollenUS_Hr-Mb", 588189, 651, 301, 84, 25, 7, seed=2),
        _T("PollenUS_Hr-Hb", 588189, 651, 301, 84, 50, 14, seed=2, approx=True),
        _T("PollenUS_VHr-Lb", 588189, 6501, 3001, 84, 100, 3, seed=2),
        _T("PollenUS_VHr-VLb", 588189, 6501, 3001, 84, 50, 3, seed=2, approx=True),
        _T("Flu_Lr-Lb", 31478, 117, 308, 851, 1, 1, seed=3),
        _T("Flu_Lr-Hb", 31478, 117, 308, 851, 3, 3, seed=3, approx=True),
        _T("Flu_Mr-Lb", 31478, 233, 615, 1985, 2, 3, seed=3),
        _T("Flu_Mr-Hb", 31478, 233, 615, 1985, 4, 7, seed=3),
        _T("Flu_Hr-Lb", 31478, 581, 1536, 5951, 5, 7, seed=3),
        _T("Flu_Hr-Hb", 31478, 581, 1536, 5951, 10, 21, seed=3),
        _T("eBird_Lr-Lb", 291990435, 357, 721, 2435, 2, 3, seed=4),
        _T("eBird_Lr-Hb", 291990435, 357, 721, 2435, 6, 5, seed=4),
        _T("eBird_Hr-Lb", 291990435, 1781, 3601, 2435, 10, 3, seed=4),
        _T("eBird_Hr-Hb", 291990435, 1781, 3601, 2435, 30, 5, seed=4),
    ]
}


def get_instance(name: str) -> STKDEInstance:
    return INSTANCES[name]


def bench_suite(max_voxels: int = 1_500_000, max_points: int = 20_000):
    """Scaled-down versions of every instance, CPU-runnable."""
    return {k: v.scaled(max_voxels, max_points) for k, v in INSTANCES.items()}
