"""Core STKDE: the paper's contribution as composable JAX modules."""
from .geometry import Domain, from_points
from . import kernels_math
from .vb import vb, vb_dec
from .pb import pb, pb_sym, VARIANTS
from . import bucketing
from .datasets import (
    STKDEInstance,
    INSTANCES,
    get_instance,
    bench_suite,
    clustered_events,
)
# keep last: api pulls in resilience, which imports core.geometry above
from .api import ChunkedResult, stkde, stkde_chunked

__all__ = [
    "ChunkedResult",
    "stkde",
    "stkde_chunked",
    "Domain",
    "from_points",
    "kernels_math",
    "vb",
    "vb_dec",
    "pb",
    "pb_sym",
    "VARIANTS",
    "bucketing",
    "STKDEInstance",
    "INSTANCES",
    "get_instance",
    "bench_suite",
    "clustered_events",
]
