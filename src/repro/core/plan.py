"""Parametric strategy planner — the paper's §6.5 future work, implemented.

"What we need to do is to develop a parametric model for the problem that
 will take into account memory availability, cost of memory initialization,
 expected cost of computing the kernel density. Using that model finding the
 best execution strategy becomes a combinatorial problem."

Given an instance (grid, bandwidths, point loads) and a device mesh, this
module prices every strategy with a three-term model (the same decomposition
the roofline analysis uses):

    time = init(HBM memset)  +  point-work(FLOPs, x imbalance)  +  collectives

and returns the argmin. Hardware constants default to TPU v5e.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from .geometry import Domain
from . import bucketing
from repro.distributed import partition


@dataclasses.dataclass(frozen=True)
class Hardware:
    peak_flops: float = 197e12   # bf16 (MXU); fp32 VPU path derated below
    hbm_bw: float = 819e9        # bytes/s
    ici_bw: float = 50e9         # bytes/s/link
    hbm_bytes: float = 16e9      # per chip
    vpu_derate: float = 0.04     # scatter path ~ VPU: few % of MXU peak
    mxu_derate: float = 0.5      # tile-GEMM path: realistic MXU fraction


V5E = Hardware()

# Rough single-host CPU constants for reconciliation smoke runs (8 fake XLA
# host devices share one socket, so per-"device" rates are fractions of the
# socket). HOST_SEED is the uncalibrated starting point; HOST below folds in
# the measured reconcile rows.
HOST_SEED = Hardware(
    peak_flops=5e10,     # per fake device, fp32 vector path
    hbm_bw=4e9,          # DRAM bandwidth share per fake device
    ici_bw=4e9,          # "collective" = memcpy through shared memory
    hbm_bytes=4e9,
    vpu_derate=1.0,      # scatter path on CPU is the same ALUs
    mxu_derate=1.0,
)

# Calibrated against results/bench/reconcile.json (mesh 2x2x2, n=8000, all
# seven probed strategies): XLA:CPU's scatter path dispatches per point,
# nowhere near vector peak — peak_flops carries the geo-mean-fitted scatter
# rate (dr/dd/pd/pd_xt/pd_xyt/hybrid compute rel-err lands within ~2x).
# dd_lpt's separable tile contraction is a GEMM and runs ~15x faster than
# the scatter strategies on the same cores, so its rate is carried
# separately in mxu_derate (see estimate()'s rate_tile). Memory-bandwidth
# (init) terms were already within ~2x and are left at their seed values,
# as is ici_bw (the collective probes measure ~ms-scale comm on shared
# memory, so a bandwidth "fit" is unidentifiable from these rows and would
# distort choose()).
HOST = dataclasses.replace(HOST_SEED, peak_flops=3.0e6, mxu_derate=15.5)


def probed_strategies() -> Tuple[str, ...]:
    """Strategy names with a phase-probe spec (``obs.reconcile.PROBED``).

    Single source of truth for which rows calibration may trust — derived
    from the probe registry so the two can never drift.
    """
    from repro.obs import reconcile

    return tuple(reconcile.PROBED)


# strategies whose compute runs on the tile-GEMM (einsum/MXU) path; every
# other strategy is on the scatter (VPU) path — see estimate()
TILE_PATH = ("dd_lpt",)


def calibrate_host(rows, base: Hardware = HOST_SEED,
                   strategies: Optional[Sequence[str]] = None) -> Hardware:
    """Re-fit the host compute rates from reconcile rows.

    ``rows`` is the ``rows`` list of a ``obs.reconcile`` report (or a path
    to one): entries with ``term == "compute_s"`` and positive
    predicted/measured values contribute ``measured / predicted`` ratios.
    ``base.peak_flops`` (the Hardware that *produced* those predictions)
    is divided by the geometric mean of the scatter-path strategies'
    ratios; ``base.mxu_derate`` is re-fitted from the ``TILE_PATH``
    strategies' ratios so the tile-GEMM rate tracks its own measurement.
    Terms other than compute are left untouched — see the HOST comment
    above.

    ``strategies`` limits which rows contribute; it defaults to the probe
    registry keys (``obs.reconcile.PROBED``) so rows from unknown or
    retired strategies in an old report can't skew the fit.
    """
    if isinstance(rows, (str, os.PathLike)):
        with open(rows) as f:
            rows = json.load(f)
    if isinstance(rows, dict):
        rows = rows.get("rows", [])
    if rows and isinstance(rows[0], dict) and "rows" in rows[0]:
        # a reconcile.json file: list of per-run reports, each with rows
        rows = [r for rep in rows for r in rep.get("rows", [])]
    allowed = set(probed_strategies() if strategies is None else strategies)

    def geomean_ratio(names):
        ratios = [
            r["measured_s"] / r["predicted_s"]
            for r in rows
            if r.get("term") == "compute_s"
            and r.get("strategy") in names
            and r.get("predicted_s", 0) > 0 and r.get("measured_s", 0) > 0
        ]
        if not ratios:
            return None
        return math.exp(sum(math.log(x) for x in ratios) / len(ratios))

    g_scatter = geomean_ratio(allowed - set(TILE_PATH))
    g_tile = geomean_ratio(allowed & set(TILE_PATH))
    out = base
    if g_scatter is not None:
        out = dataclasses.replace(out, peak_flops=base.peak_flops / g_scatter)
    if g_tile is not None:
        # tile rate = peak_flops * mxu_derate must shrink by g_tile; the
        # peak_flops change above is compensated inside the derate
        scale = g_scatter if g_scatter is not None else 1.0
        out = dataclasses.replace(
            out, mxu_derate=base.mxu_derate * scale / g_tile)
    return out


def default_hw() -> Hardware:
    """The Hardware model matching the active JAX backend (HOST on cpu)."""
    try:
        import jax

        backend = jax.default_backend()
    except Exception:
        backend = "cpu"
    return HOST if backend == "cpu" else V5E


def _point_work_flops(dom: Domain, n_eff: float) -> float:
    """PB-SYM flops: disk eval + bar eval + cylinder outer-product FMA."""
    disk = (2 * dom.Hs + 1) ** 2
    bar = 2 * dom.Ht + 1
    return n_eff * (disk * 10.0 + bar * 5.0 + disk * bar * 2.0)


def estimate(
    dom: Domain,
    n: int,
    mesh_shape: Tuple[int, ...],
    loads: Optional[np.ndarray] = None,
    hw: Hardware = V5E,
    use_mxu: bool = True,
) -> Dict[str, Dict[str, float]]:
    """Per-strategy cost breakdown in seconds. mesh_shape=(A, B) or (R, A, B)."""
    if len(mesh_shape) == 3:
        R, A, B = mesh_shape
    else:
        R, (A, B) = 1, mesh_shape
    P = R * A * B
    Gb = dom.grid_voxels * 4.0                      # grid bytes
    gx_loc = math.ceil(dom.Gx / A)
    gy_loc = math.ceil(dom.Gy / B)
    sub_b = gx_loc * gy_loc * dom.Gt * 4.0
    halo_b = 2 * (gx_loc + gy_loc + 2 * dom.Hs) * dom.Hs * dom.Gt * 4.0
    # Two compute paths with very different efficiency: the scatter-based
    # PB-SYM strategies (dr/dd/pd/pd_xt/pd_xyt/hybrid) run at the VPU
    # rate, while dd_lpt's separable tile contraction is a GEMM (MXU)
    # workload. Pricing them with one shared rate hid a >10x compute
    # misprediction for dd_lpt in the reconcile rows.
    rate_scatter = hw.peak_flops * hw.vpu_derate
    rate_tile = hw.peak_flops * (
        hw.mxu_derate if use_mxu else hw.vpu_derate
    )

    # overlap replication factor (cut cylinders) for DD-style strategies
    tiles_per_dim_x = max(1.0, gx_loc / (2 * dom.Hs + 1))
    rep_dd = (1 + 1 / tiles_per_dim_x) * (
        1 + 1 / max(1.0, gy_loc / (2 * dom.Hs + 1))
    )

    # imbalance: measured from per-bucket loads when available
    if loads is not None:
        stats_ab = partition.imbalance_stats(loads, A * B)
        imb_block = stats_ab["block_imbalance"]
        imb_lpt = stats_ab["lpt_imbalance"]
    else:
        imb_block, imb_lpt = 2.5, 1.05              # pessimistic defaults

    w = _point_work_flops(dom, float(n))
    out: Dict[str, Dict[str, float]] = {}

    def entry(init_b, flops, imb, comm_b, mem_b, rate=rate_scatter):
        compute_s = flops * imb / (P * rate)
        return {
            "init_s": init_b / hw.hbm_bw,
            "compute_s": compute_s,
            "comm_s": comm_b / hw.ici_bw,
            "mem_per_dev_gb": mem_b / 1e9,
            "feasible": float(mem_b < hw.hbm_bytes),
            "total_s": init_b / hw.hbm_bw + compute_s + comm_b / hw.ici_bw,
        }

    # DR: full grid per device; ring all-reduce ~ 2*Gb*(P-1)/P per device
    out["dr"] = entry(Gb, w, 1.0, 2 * Gb * (P - 1) / P, 2 * Gb)
    # DD: subgrid per device; replicated points; no comm
    out["dd"] = entry(sub_b, w * rep_dd, imb_block, 0.0, sub_b)
    # PD: halo-extended subgrid; halo exchange; work-efficient
    pd_feasible = gx_loc >= dom.Hs and gy_loc >= dom.Hs
    out["pd"] = entry(
        (gx_loc + 2 * dom.Hs) * (gy_loc + 2 * dom.Hs) * dom.Gt * 4.0,
        w,
        imb_block,
        halo_b,
        sub_b * 2,
    )
    out["pd"]["feasible"] *= float(pd_feasible)
    # PD-XT: split (X, T) — temporal halos are Ht-wide (cheap for
    # long-duration instances); Y unsharded.
    gt_loc = math.ceil(dom.Gt / B)
    halo_xt = 2 * (dom.Hs * dom.Gy * (gt_loc + 2 * dom.Ht)
                   + dom.Ht * gx_loc * dom.Gy) * 4.0
    out["pd_xt"] = entry(
        (gx_loc + 2 * dom.Hs) * dom.Gy * (gt_loc + 2 * dom.Ht) * 4.0,
        w,
        imb_block,
        halo_xt,
        gx_loc * dom.Gy * gt_loc * 4.0 * 2,
    )
    out["pd_xt"]["feasible"] *= float(
        gx_loc >= dom.Hs and gt_loc >= dom.Ht)
    # PD-XYT: full 3-D split — a 3-tuple mesh_shape is read as the
    # (X, Y, T) device grid for this entry (the leading axis splits X
    # instead of replicating). On a 2-D mesh there is no T axis to
    # split, so the strategy is priced like pd but marked infeasible.
    if len(mesh_shape) == 3:
        X, Y, T = mesh_shape
        gx3 = math.ceil(dom.Gx / X)
        gy3 = math.ceil(dom.Gy / Y)
        gt3 = math.ceil(dom.Gt / T)
        halo_xyt = 2 * (
            dom.Hs * gy3 * gt3 + dom.Hs * gx3 * gt3 + dom.Ht * gx3 * gy3
        ) * 4.0
        out["pd_xyt"] = entry(
            (gx3 + 2 * dom.Hs) * (gy3 + 2 * dom.Hs)
            * (gt3 + 2 * dom.Ht) * 4.0,
            w,
            imb_block,
            halo_xyt,
            gx3 * gy3 * gt3 * 4.0 * 2,
        )
        out["pd_xyt"]["feasible"] *= float(
            gx3 >= dom.Hs and gy3 >= dom.Hs and gt3 >= dom.Ht)
    else:
        out["pd_xyt"] = dict(out["pd"])
        out["pd_xyt"]["feasible"] = 0.0
    # DD-LPT: full grid per device (tile soup assembly via psum); the
    # only strategy on the tile-GEMM compute path
    out["dd_lpt"] = entry(
        Gb, w * rep_dd, imb_lpt, 2 * Gb * (P - 1) / P, 2 * Gb,
        rate=rate_tile,
    )
    # hybrid (R-way REP over PD): psum of subgrids over R + halo
    out["hybrid"] = entry(
        (gx_loc + 2 * dom.Hs) * (gy_loc + 2 * dom.Hs) * dom.Gt * 4.0,
        w,
        max(1.0, imb_block / R),
        halo_b + 2 * sub_b * (R - 1) / R,
        sub_b * 2,
    )
    out["hybrid"]["feasible"] *= float(pd_feasible)
    return out


def choose(
    dom: Domain,
    n: int,
    mesh_shape: Tuple[int, ...],
    loads: Optional[np.ndarray] = None,
    hw: Hardware = V5E,
) -> Tuple[str, Dict[str, Dict[str, float]]]:
    """Best feasible strategy and the full cost table."""
    table = estimate(dom, n, mesh_shape, loads, hw)
    feas = {k: v for k, v in table.items() if v["feasible"] > 0}
    pick = min(feas or table, key=lambda k: (feas or table)[k]["total_s"])
    return pick, table
