"""Voxel-based STKDE algorithms: VB (gold standard) and VB-DEC.

``VB`` follows Algorithm 1 of the paper verbatim: for every voxel, scan all
points, test the cylinder condition, and accumulate the kernel product.
Complexity Theta(Gx*Gy*Gt*n) — it exists as the correctness gold standard and
as the slow baseline of Table 3.

``VB-DEC`` is the paper's improved voxel-based variant: points are bucketed
into bandwidth-sized cells so each voxel only tests points that can reach it
(the 3x3x3 neighborhood of its cell). It shares the bucketing substrate with
the Pallas tile kernel (``core/bucketing.py``).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .geometry import Domain
from . import kernels_math as km
from . import bucketing


def _vb_slice(points, valid, xc, yc, tc, dom: Domain, ks, kt):
    """Density of one temporal slice: (Gx, Gy) given all points.

    points: (n, 3), valid: (n,), xc: (Gx,), yc: (Gy,), tc: scalar.
    """
    px, py, pt = points[:, 0], points[:, 1], points[:, 2]
    # (Gx, n) and (Gy, n) offsets; the cylinder test is evaluated per voxel
    # exactly as Algorithm 1 does.
    u = (xc[:, None] - px[None, :]) / dom.hs          # (Gx, n)
    v = (yc[:, None] - py[None, :]) / dom.hs          # (Gy, n)
    w = (tc - pt) / dom.ht                            # (n,)
    ksv = ks(u[:, None, :], v[None, :, :])            # (Gx, Gy, n)
    ktv = kt(w)                                       # (n,)
    contrib = ksv * (ktv * valid)[None, None, :]
    return contrib.sum(axis=-1)


@functools.partial(jax.jit, static_argnames=("dom", "ks", "kt"))
def vb(
    points: jnp.ndarray,
    dom: Domain,
    ks: km.SpatialKernel = km.DEFAULT_KS,
    kt: km.TemporalKernel = km.DEFAULT_KT,
) -> jnp.ndarray:
    """Gold-standard voxel-based STKDE. Returns (Gx, Gy, Gt) fp32 grid."""
    n = points.shape[0]
    xc = dom.voxel_centers_x()
    yc = dom.voxel_centers_y()
    tcs = dom.voxel_centers_t()
    valid = jnp.ones((n,), dtype=jnp.float32)
    norm = km.normalization(n, dom.hs, dom.ht)

    def slice_body(carry, tc):
        s = _vb_slice(points, valid, xc, yc, tc, dom, ks, kt)
        return carry, s * norm

    _, slices = jax.lax.scan(slice_body, 0, tcs)      # (Gt, Gx, Gy)
    return jnp.transpose(slices, (1, 2, 0)).astype(jnp.float32)


@functools.partial(
    jax.jit, static_argnames=("dom", "ks", "kt", "tile", "cap", "n_total")
)
def _vb_dec_impl(
    pts_tiles, valid_tiles, dom: Domain, ks, kt, tile, cap, n_total
):
    """Per-tile VB over bucketed candidate points.

    pts_tiles: (ntx, nty, ntt, cap, 3); valid: (ntx, nty, ntt, cap).
    """
    bx, by, bt = tile
    norm = km.normalization(n_total, dom.hs, dom.ht)

    def one_tile(tix, tiy, tit, pts, vld):
        # voxel centers of this tile
        x0 = tix * bx
        y0 = tiy * by
        t0 = tit * bt
        xc = dom.ox + (x0 + jnp.arange(bx, dtype=jnp.float32) + 0.5) * dom.sres
        yc = dom.oy + (y0 + jnp.arange(by, dtype=jnp.float32) + 0.5) * dom.sres
        tc = dom.ot + (t0 + jnp.arange(bt, dtype=jnp.float32) + 0.5) * dom.tres
        u = (xc[:, None] - pts[None, :, 0]) / dom.hs       # (bx, cap)
        v = (yc[:, None] - pts[None, :, 1]) / dom.hs       # (by, cap)
        w = (tc[:, None] - pts[None, :, 2]) / dom.ht       # (bt, cap)
        ksv = ks(u[:, None, :], v[None, :, :])             # (bx, by, cap)
        ktv = kt(w) * vld[None, :]                         # (bt, cap)
        return jnp.einsum("xyp,tp->xyt", ksv, ktv) * norm

    ntx, nty, ntt = pts_tiles.shape[:3]
    tix = jnp.arange(ntx)
    tiy = jnp.arange(nty)
    tit = jnp.arange(ntt)
    f = jax.vmap(
        jax.vmap(
            jax.vmap(one_tile, in_axes=(None, None, 0, 0, 0)),
            in_axes=(None, 0, None, 0, 0),
        ),
        in_axes=(0, None, None, 0, 0),
    )
    tiles = f(tix, tiy, tit, pts_tiles, valid_tiles)  # (ntx,nty,ntt,bx,by,bt)
    grid = jnp.transpose(tiles, (0, 3, 1, 4, 2, 5)).reshape(
        ntx * bx, nty * by, ntt * bt
    )
    return grid[: dom.Gx, : dom.Gy, : dom.Gt]


def vb_dec(
    points,
    dom: Domain,
    ks: km.SpatialKernel = km.DEFAULT_KS,
    kt: km.TemporalKernel = km.DEFAULT_KT,
    tile: Optional[tuple] = None,
    cap: Optional[int] = None,
) -> jnp.ndarray:
    """VB with bandwidth-sized point decomposition (paper's VB-DEC).

    Buckets points with cylinder overlap into tiles (>= bandwidth sized), then
    runs the voxel scan per tile against only candidate points.
    """
    import numpy as np

    pts = np.asarray(points)
    if tile is None:
        tile = bucketing.default_tile(dom)
    b = bucketing.bucket_points_overlap(pts, dom, tile, cap=cap)
    return _vb_dec_impl(
        jnp.asarray(b.points),
        jnp.asarray(b.valid.astype(np.float32)),
        dom,
        ks,
        kt,
        tile,
        b.cap,
        pts.shape[0],
    )
