"""Top-level STKDE public API: one call, strategy auto-selected.

    from repro.core.api import stkde
    grid = stkde(points, dom)                       # single device
    grid = stkde(points, dom, mesh=mesh)            # auto strategy on mesh
    grid = stkde(points, dom, mesh=mesh, strategy="pd")
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np

from .geometry import Domain
from . import kernels_math as km
from .pb import pb as _pb
from . import plan as _plan


def stkde(
    points,
    dom: Domain,
    mesh=None,
    strategy: str = "auto",
    axes: Tuple[str, str] = ("data", "model"),
    rep_axis: Optional[str] = None,
    ks: km.SpatialKernel = km.DEFAULT_KS,
    kt: km.TemporalKernel = km.DEFAULT_KT,
    use_tiled_kernel: bool = False,
) -> jnp.ndarray:
    """Space-time kernel density grid for ``points`` over ``dom``.

    strategy: "auto" | "dr" | "dd" | "pd" | "dd_lpt" | "hybrid"
              (single-device when mesh is None: scatter PB-SYM, or the
              Pallas tiled kernel with use_tiled_kernel=True).
    """
    pts = np.asarray(points, dtype=np.float32)
    if mesh is None:
        if use_tiled_kernel:
            from repro.kernels import stkde_tiled

            return stkde_tiled(pts, dom, ks=ks, kt=kt)
        return _pb(pts, dom, variant="sym", ks=ks, kt=kt)

    from repro.distributed import STRATEGIES
    from . import bucketing

    if strategy == "auto":
        A = mesh.shape[axes[0]]
        B = mesh.shape[axes[1]]
        shape = (
            (mesh.shape[rep_axis], A, B) if rep_axis is not None else (A, B)
        )
        import math

        tile = (math.ceil(dom.Gx / A), math.ceil(dom.Gy / B), dom.Gt)
        loads = bucketing.bucket_points_home(pts, dom, tile).counts
        strategy, _ = _plan.choose(dom, len(pts), shape, loads.reshape(-1))
        if strategy == "hybrid" and rep_axis is None:
            strategy = "pd"
    fn = STRATEGIES[strategy]
    kw = dict(axes=axes, ks=ks, kt=kt)
    if strategy == "hybrid":
        kw["rep_axis"] = rep_axis or "pod"
    return fn(pts, dom, mesh, **kw)
