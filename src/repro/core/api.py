"""Top-level STKDE public API: one call, strategy auto-selected.

    from repro.core.api import stkde
    grid = stkde(points, dom)                       # single device
    grid = stkde(points, dom, mesh=mesh)            # auto strategy on mesh
    grid = stkde(points, dom, mesh=mesh, strategy="pd")

Robustness contract (docs/resilience.md): inputs are validated at this
boundary (typed ``ReproValidationError`` instead of downstream shape
errors), outputs are NaN/Inf-checked, and a failed distributed strategy
build/execution falls back to the ``dr`` baseline (counted in
``resilience.fallbacks``) unless ``fallback=False``.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.resilience.degrade import ensure_finite
from repro.resilience.errors import ReproError, ReproValidationError

from .geometry import Domain
from . import kernels_math as km
from .pb import pb as _pb
from . import plan as _plan


def validate_inputs(points, dom: Domain) -> np.ndarray:
    """API-boundary validation; returns points as float32 ``(n, 3)``.

    Rejects (typed ``ReproValidationError``): empty point sets, wrong
    shapes, NaN/Inf coordinates, non-positive bandwidths/resolutions,
    and time coordinates outside the domain's time window (± one
    temporal bandwidth — points just outside still radiate density in).
    """
    pts = np.asarray(points, dtype=np.float32)
    if pts.ndim != 2 or pts.shape[1] != 3:
        raise ReproValidationError(
            f"points must be (n, 3) [x, y, t]; got shape {pts.shape}"
        )
    if len(pts) == 0:
        raise ReproValidationError("empty point set")
    if not np.isfinite(pts).all():
        bad = int(len(pts) - np.isfinite(pts).all(axis=1).sum())
        raise ReproValidationError(
            f"{bad}/{len(pts)} points have NaN/Inf coordinates"
        )
    if not (dom.hs > 0 and dom.ht > 0):
        raise ReproValidationError(
            f"bandwidths must be positive: hs={dom.hs} ht={dom.ht}"
        )
    if not (dom.sres > 0 and dom.tres > 0):
        raise ReproValidationError(
            f"resolutions must be positive: sres={dom.sres} tres={dom.tres}"
        )
    t_lo, t_hi = dom.ot - dom.ht, dom.ot + dom.gt + dom.ht
    t = pts[:, 2]
    if t.min() < t_lo or t.max() > t_hi:
        n_out = int(((t < t_lo) | (t > t_hi)).sum())
        raise ReproValidationError(
            f"{n_out}/{len(pts)} points outside the domain time window "
            f"[{t_lo}, {t_hi}] (ot={dom.ot} gt={dom.gt} ht={dom.ht})"
        )
    return pts


def stkde(
    points,
    dom: Domain,
    mesh=None,
    strategy: str = "auto",
    axes: Tuple[str, str] = ("data", "model"),
    rep_axis: Optional[str] = None,
    ks: km.SpatialKernel = km.DEFAULT_KS,
    kt: km.TemporalKernel = km.DEFAULT_KT,
    use_tiled_kernel: bool = False,
    validate: bool = True,
    fallback: bool = True,
) -> jnp.ndarray:
    """Space-time kernel density grid for ``points`` over ``dom``.

    strategy: "auto" | "dr" | "dd" | "pd" | "dd_lpt" | "hybrid"
              (single-device when mesh is None: scatter PB-SYM, or the
              Pallas tiled kernel with use_tiled_kernel=True).
    validate: typed input validation at this boundary (see
              ``validate_inputs``).
    fallback: on mesh strategy build/execution failure or non-finite
              output, retry once with the ``dr`` baseline.
    """
    if validate:
        pts = validate_inputs(points, dom)
    else:
        pts = np.asarray(points, dtype=np.float32)
    if mesh is None:
        if use_tiled_kernel:
            from repro.kernels import stkde_tiled

            return ensure_finite(stkde_tiled(pts, dom, ks=ks, kt=kt),
                                 "stkde.tiled")
        return ensure_finite(
            _pb(pts, dom, variant="sym", ks=ks, kt=kt), "stkde.pb"
        )

    from repro.distributed import STRATEGIES
    from . import bucketing

    if strategy == "auto":
        A = mesh.shape[axes[0]]
        B = mesh.shape[axes[1]]
        shape = (
            (mesh.shape[rep_axis], A, B) if rep_axis is not None else (A, B)
        )
        import math

        tile = (math.ceil(dom.Gx / A), math.ceil(dom.Gy / B), dom.Gt)
        loads = bucketing.bucket_points_home(pts, dom, tile).counts
        strategy, _ = _plan.choose(dom, len(pts), shape, loads.reshape(-1))
        if strategy == "hybrid" and rep_axis is None:
            strategy = "pd"
    fn = STRATEGIES[strategy]
    kw = dict(axes=axes, ks=ks, kt=kt)
    if strategy == "hybrid":
        kw["rep_axis"] = rep_axis or "pod"
    try:
        return ensure_finite(fn(pts, dom, mesh, **kw),
                             f"stkde.{strategy}")
    except (ReproError, ValueError) as e:
        if not fallback or strategy == "dr":
            raise
        from repro import obs

        obs.counter("resilience.fallbacks").inc()
        obs.counter(f"resilience.fallbacks.stkde.{strategy}").inc()
        with obs.span("resilience.fallback", frm=strategy, to="dr",
                      error=type(e).__name__):
            out = STRATEGIES["dr"](pts, dom, mesh, axes=axes, ks=ks,
                                   kt=kt)
        return ensure_finite(out, "stkde.dr")
