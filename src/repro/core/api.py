"""Top-level STKDE public API: one call, strategy auto-selected.

    from repro.core.api import stkde
    grid = stkde(points, dom)                       # single device
    grid = stkde(points, dom, mesh=mesh)            # auto strategy on mesh
    grid = stkde(points, dom, mesh=mesh, strategy="pd")
    res = stkde(points, dom, chunk_size=4096,       # crash-safe chunked run
                journal="runs/j1")                  # -> ChunkedResult
    res = stkde(points, dom, resume="runs/j1")      # salvage + continue
    grid = np.asarray(res)                          # or res.grid

Robustness contract (docs/resilience.md): inputs are validated at this
boundary (typed ``ReproValidationError`` instead of downstream shape
errors), outputs are NaN/Inf-checked, and a failed distributed strategy
build/execution falls back to the ``dr`` baseline (counted in
``resilience.fallbacks``) unless ``fallback=False``. Chunked execution
(``stkde_chunked``) additionally journals per-chunk progress to disk so
a killed run resumes bit-identically, and survives injected device loss
by re-planning the remaining chunks onto a shrunken mesh.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple, Union

import jax.numpy as jnp
import numpy as np

from repro.resilience.degrade import ensure_finite
from repro.resilience.errors import (
    DeviceLostError,
    ReproError,
    ReproValidationError,
    RetriesExhaustedError,
)
from repro.resilience.retry import RetryPolicy, with_retry

from .geometry import Domain
from . import kernels_math as km
from .pb import pb as _pb
from . import plan as _plan


def validate_inputs(points, dom: Domain) -> np.ndarray:
    """API-boundary validation; returns points as float32 ``(n, 3)``.

    Rejects (typed ``ReproValidationError``): empty point sets, wrong
    shapes, NaN/Inf coordinates, non-positive bandwidths/resolutions,
    and time coordinates outside the domain's time window (± one
    temporal bandwidth — points just outside still radiate density in).
    """
    pts = np.asarray(points, dtype=np.float32)
    if pts.ndim != 2 or pts.shape[1] != 3:
        raise ReproValidationError(
            f"points must be (n, 3) [x, y, t]; got shape {pts.shape}"
        )
    if len(pts) == 0:
        raise ReproValidationError("empty point set")
    if not np.isfinite(pts).all():
        bad = int(len(pts) - np.isfinite(pts).all(axis=1).sum())
        raise ReproValidationError(
            f"{bad}/{len(pts)} points have NaN/Inf coordinates"
        )
    if not (dom.hs > 0 and dom.ht > 0):
        raise ReproValidationError(
            f"bandwidths must be positive: hs={dom.hs} ht={dom.ht}"
        )
    if not (dom.sres > 0 and dom.tres > 0):
        raise ReproValidationError(
            f"resolutions must be positive: sres={dom.sres} tres={dom.tres}"
        )
    t_lo, t_hi = dom.ot - dom.ht, dom.ot + dom.gt + dom.ht
    t = pts[:, 2]
    if t.min() < t_lo or t.max() > t_hi:
        n_out = int(((t < t_lo) | (t > t_hi)).sum())
        raise ReproValidationError(
            f"{n_out}/{len(pts)} points outside the domain time window "
            f"[{t_lo}, {t_hi}] (ot={dom.ot} gt={dom.gt} ht={dom.ht})"
        )
    return pts


def stkde(
    points,
    dom: Domain,
    mesh=None,
    strategy: str = "auto",
    axes: Tuple[str, str] = ("data", "model"),
    rep_axis: Optional[str] = None,
    ks: km.SpatialKernel = km.DEFAULT_KS,
    kt: km.TemporalKernel = km.DEFAULT_KT,
    use_tiled_kernel: bool = False,
    validate: bool = True,
    fallback: bool = True,
    chunk_size: Optional[int] = None,
    journal: Optional[str] = None,
    resume: Optional[str] = None,
) -> Union[jnp.ndarray, "ChunkedResult"]:
    """Space-time kernel density grid for ``points`` over ``dom``.

    strategy: "auto" | "dr" | "dd" | "pd" | "dd_lpt" | "hybrid"
              (single-device when mesh is None: scatter PB-SYM, or the
              Pallas tiled kernel with use_tiled_kernel=True).
    validate: typed input validation at this boundary (see
              ``validate_inputs``).
    fallback: on mesh strategy build/execution failure or non-finite
              output, retry once with the ``dr`` baseline.
    chunk_size / journal / resume: any of these switches to crash-safe
              chunked execution (``stkde_chunked``): bounded-memory chunk
              ingestion, per-chunk progress journaling to the ``journal``
              directory, and ``resume=<journal dir>`` salvaging a killed
              run's completed chunks before continuing. The chunked path
              returns a ``ChunkedResult`` (array-like: ``np.asarray(res)``
              or ``res.grid`` is the float64 accumulator grid; ``.report``
              carries coverage/recovery details).
    """
    if chunk_size is not None or journal is not None or resume is not None:
        return stkde_chunked(
            points, dom, mesh=mesh, strategy=strategy, axes=axes,
            rep_axis=rep_axis, ks=ks, kt=kt, chunk_size=chunk_size,
            journal=resume if resume is not None else journal,
            resume=resume is not None, validate=validate,
        )
    if validate:
        pts = validate_inputs(points, dom)
    else:
        pts = np.asarray(points, dtype=np.float32)
    if mesh is None:
        if use_tiled_kernel:
            from repro.kernels import stkde_tiled

            return ensure_finite(stkde_tiled(pts, dom, ks=ks, kt=kt),
                                 "stkde.tiled")
        return ensure_finite(
            _pb(pts, dom, variant="sym", ks=ks, kt=kt), "stkde.pb"
        )

    from repro.distributed import STRATEGIES
    from . import bucketing

    if strategy == "auto":
        A = mesh.shape[axes[0]]
        B = mesh.shape[axes[1]]
        shape = (
            (mesh.shape[rep_axis], A, B) if rep_axis is not None else (A, B)
        )
        import math

        tile = (math.ceil(dom.Gx / A), math.ceil(dom.Gy / B), dom.Gt)
        loads = bucketing.bucket_points_home(pts, dom, tile).counts
        strategy, _ = _plan.choose(dom, len(pts), shape, loads.reshape(-1))
        if strategy in ("hybrid", "pd_xyt") and rep_axis is None:
            strategy = "pd"
    fn = STRATEGIES[strategy]
    kw = dict(axes=axes, ks=ks, kt=kt)
    if strategy == "hybrid":
        kw["rep_axis"] = rep_axis or "pod"
    elif strategy == "pd_xyt" and len(axes) == 2:
        # 3-D split needs a third mesh axis: the rep axis becomes the X cut
        kw["axes"] = (rep_axis or "pod",) + tuple(axes)
    try:
        return ensure_finite(fn(pts, dom, mesh, **kw),
                             f"stkde.{strategy}")
    except (ReproError, ValueError) as e:
        if not fallback or strategy == "dr":
            raise
        from repro import obs

        obs.counter("resilience.fallbacks").inc()
        obs.counter(f"resilience.fallbacks.stkde.{strategy}").inc()
        with obs.span("resilience.fallback", frm=strategy, to="dr",
                      error=type(e).__name__):
            out = STRATEGIES["dr"](pts, dom, mesh, axes=axes, ks=ks,
                                   kt=kt)
        return ensure_finite(out, "stkde.dr")


# ------------------------------------------------------------------ chunked
DEFAULT_CHUNK = 4096

# per-chunk transient faults (injected OOMs, IO hiccups) retry in place; a
# chunk that keeps failing on a mesh is treated as a device/mesh failure
_CHUNK_POLICY = RetryPolicy(max_attempts=3, base_delay_s=0.01,
                            max_delay_s=0.2)


@dataclasses.dataclass
class ChunkedResult:
    """Result of a chunked (crash-safe) STKDE run — the single result type
    of the chunked surface (returned by ``stkde_chunked`` *and* by
    ``stkde`` whenever ``chunk_size``/``journal``/``resume`` engage the
    chunked path).

    ``grid`` is the float64 accumulator — chunk contributions are summed
    host-side in float64 *in fixed chunk order*, which is what makes an
    interrupted-and-resumed run bit-identical to an uninterrupted one.
    The object is array-like (``__array__`` forwards to ``grid``), so
    ``np.asarray(result)`` and numpy ufuncs keep working for callers that
    only want the density grid.
    """

    grid: np.ndarray
    report: Dict[str, Any]
    journal_path: Optional[str] = None

    def __array__(self, dtype=None):
        return (np.asarray(self.grid) if dtype is None
                else np.asarray(self.grid, dtype=dtype))


def _chunk_fingerprint(dom: Domain, n_total: int, chunk_desc, strategy: str,
                       ks, kt) -> str:
    from repro.resilience.journal import fingerprint_of

    return fingerprint_of(
        dom=dataclasses.asdict(dom), n_total=int(n_total),
        chunk_size=chunk_desc, strategy=strategy,
        ks=getattr(ks, "__name__", str(ks)),
        kt=getattr(kt, "__name__", str(kt)), version=1,
    )


def _replan_after_loss(dom: Domain, n_total: int, mesh, axes, rep_axis):
    """Pick (mesh, strategy) for the chunks remaining after a device loss.

    Shrinks the mesh by one device and re-runs the parametric planner
    with the calibrated hardware model; when no multi-device mesh
    survives, degrades to single-device local execution (strategy
    ``local``).
    """
    from repro.launch import mesh as _mesh_lib

    new_mesh = _mesh_lib.shrink_mesh(mesh, 1)
    if new_mesh is None:
        return None, "local"
    A = new_mesh.shape[axes[0]]
    B = new_mesh.shape[axes[1]]
    shape = ((new_mesh.shape[rep_axis], A, B) if rep_axis is not None
             else (A, B))
    strat, _ = _plan.choose(dom, n_total, shape, None,
                            hw=_plan.default_hw())
    if strat in ("hybrid", "pd_xyt") and rep_axis is None:
        strat = "pd"
    return new_mesh, strat


def stkde_chunked(
    points,
    dom: Domain,
    mesh=None,
    strategy: str = "auto",
    axes: Tuple[str, str] = ("data", "model"),
    rep_axis: Optional[str] = None,
    ks: km.SpatialKernel = km.DEFAULT_KS,
    kt: km.TemporalKernel = km.DEFAULT_KT,
    chunk_size: Optional[int] = None,
    journal: Optional[str] = None,
    resume: bool = False,
    validate: bool = True,
    keep_snapshots: int = 2,
    max_chunks: Optional[int] = None,
    n_total: Optional[int] = None,
) -> ChunkedResult:
    """Crash-safe chunked STKDE: bounded memory, durable progress, and
    device-loss recovery (docs/resilience.md "Resumable execution").

    ``points`` is an in-memory ``(n, 3)`` array (sliced into
    ``chunk_size`` pieces) or a chunk stream (``data.pipeline
    .stkde_stream``, or any iterable of chunk arrays plus ``n_total=``) —
    peak point-buffer memory is one chunk either way. Each chunk's grid
    contribution is accumulated host-side in float64; with ``journal=``
    every landed chunk appends a CRC-verified record + accumulator
    snapshot, and ``resume=True`` salvages completed chunks from that
    journal before computing the rest. ``max_chunks`` bounds how many
    chunks this call computes (cooperative time-slicing: call again with
    ``resume=True`` to continue; the report's ``coverage`` < 1 flags the
    partial state).

    On a mesh, an injected device failure (``dist.device`` site) —
    or a chunk whose retries exhaust — re-plans the remaining chunks
    onto a shrunken mesh via ``plan.choose``/``launch.mesh.shrink_mesh``
    (ultimately degrading to single-device execution) and tags the
    result's ``report["recovery"]`` instead of raising.
    """
    from repro import obs
    from repro.data.pipeline import as_chunks
    from repro.resilience import faults as _faults
    from repro.resilience.journal import ProgressJournal
    from . import bucketing

    is_array = isinstance(points, (np.ndarray, list, tuple))
    if is_array:
        points = (validate_inputs(points, dom) if validate
                  else np.asarray(points, dtype=np.float32))

    jnl = None
    if journal is not None:
        jnl = ProgressJournal(journal, keep=keep_snapshots)
        if resume and chunk_size is None and is_array and jnl.exists():
            # stkde(..., resume=path) convenience: recover the original
            # chunk size from the journal's meta record
            m = jnl.meta()
            if m is not None:
                cs = m.get("meta", {}).get("chunk_size")
                chunk_size = cs if isinstance(cs, int) else None
    if is_array and chunk_size is None:
        chunk_size = DEFAULT_CHUNK
    chunks, n_total = as_chunks(points, chunk_size, n_total)
    chunk_desc: Union[int, str] = chunk_size if is_array else "stream"

    requested = strategy
    if mesh is None:
        strat = "local"
    elif strategy == "auto":
        A, B = mesh.shape[axes[0]], mesh.shape[axes[1]]
        shape = ((mesh.shape[rep_axis], A, B) if rep_axis is not None
                 else (A, B))
        if is_array:
            import math

            tile = (math.ceil(dom.Gx / A), math.ceil(dom.Gy / B), dom.Gt)
            loads = bucketing.bucket_points_home(points, dom, tile).counts
            loads = loads.reshape(-1)
        else:
            loads = None  # streams can't be pre-bucketed; use defaults
        strat, _ = _plan.choose(dom, n_total, shape, loads,
                                hw=_plan.default_hw())
        if strat in ("hybrid", "pd_xyt") and rep_axis is None:
            strat = "pd"
    else:
        strat = strategy

    fp = _chunk_fingerprint(dom, n_total, chunk_desc, requested, ks, kt)
    meta = {
        "n_total": int(n_total), "chunk_size": chunk_desc,
        "strategy": requested, "grid_shape": list(dom.grid_shape),
    }
    salvage = None
    if jnl is not None:
        if resume and jnl.exists():
            s = jnl.replay(expect_fingerprint=fp, truncate=True)
            if s.meta is None:
                # journal died before its meta record landed: fresh start
                jnl.create(fp, meta)
            else:
                salvage = s
        else:
            jnl.create(fp, meta)

    if salvage is not None and salvage.grid is not None:
        acc = np.array(salvage.grid, dtype=np.float64)
    else:
        acc = np.zeros(dom.grid_shape, dtype=np.float64)
    salvaged_id = salvage.chunk_id if salvage is not None else -1

    mesh_now, strat_now = mesh, strat
    recovery: List[Dict[str, Any]] = []
    if salvage is not None:
        recovery.extend(salvage.events)
    computed = 0
    done_stop = (salvage.ranges[salvaged_id][1]
                 if salvage is not None and salvaged_id >= 0 else 0)
    max_chunk_points = 0
    chunks_seen = 0
    cap_run = 0
    truncated = False

    def mesh_shape_of(m):
        return (tuple(int(m.shape[a]) for a in m.axis_names)
                if m is not None else None)

    for cid, start, stop, cpts in chunks:
        chunks_seen = cid + 1
        if cid <= salvaged_id:
            got = (int(start), int(stop))
            want = tuple(salvage.ranges.get(cid, (None, None)))
            if got != want:
                raise ReproValidationError(
                    f"resume point-range mismatch at chunk {cid}: source "
                    f"yields {got} but the journal recorded {want} — the "
                    "point source differs from the original run"
                )
            continue  # salvaged from the journal: skip recomputation
        if max_chunks is not None and computed >= max_chunks:
            truncated = True
            break
        if not is_array and validate:
            cpts = validate_inputs(cpts, dom)
        max_chunk_points = max(max_chunk_points, len(cpts))
        cap_run = max(cap_run, bucketing.round_up(max(8, len(cpts)), 8))

        def attempt(cpts=cpts):
            _faults.fault_point("stkde.chunk")
            if mesh_now is None:
                g = _pb(cpts, dom, variant="sym", ks=ks, kt=kt,
                        n_total=n_total)
            else:
                from repro.distributed.stkde_dist import execute_chunk

                g = execute_chunk(
                    cpts, dom, mesh_now, strat_now, axes=axes,
                    rep_axis=rep_axis, cap=cap_run, ks=ks, kt=kt,
                    n_total=n_total)
            return ensure_finite(np.asarray(g), f"stkde.chunk.{cid}")

        with obs.span("chunk.compute", chunk=cid, n=len(cpts),
                      strategy=strat_now):
            while True:
                try:
                    g = with_retry(attempt, policy=_CHUNK_POLICY,
                                   site="stkde.chunk")
                    break
                except (DeviceLostError, RetriesExhaustedError) as e:
                    if mesh_now is None:
                        raise  # local execution has no mesh to shrink
                    old_shape = mesh_shape_of(mesh_now)
                    mesh_now, strat_now = _replan_after_loss(
                        dom, n_total, mesh_now, axes, rep_axis)
                    event = {
                        "event": "device_lost", "chunk_id": int(cid),
                        "error": type(e).__name__,
                        "from_mesh": list(old_shape),
                        "to_mesh": (list(mesh_shape_of(mesh_now))
                                    if mesh_now is not None else None),
                        "strategy": strat_now,
                    }
                    recovery.append(event)
                    if jnl is not None:
                        jnl.append_event(event)
                    obs.counter("chunk.device_lost").inc()
                    obs.counter("chunk.replans").inc()

        acc += np.asarray(g, dtype=np.float64)
        computed += 1
        done_stop = int(stop)
        obs.counter("chunk.computed").inc()
        obs.histogram("chunk.points").observe(len(cpts))
        if jnl is not None:
            jnl.append_chunk(cid, start, stop, acc, strategy=strat_now,
                             mesh=mesh_shape_of(mesh_now))

    report = {
        "n_total": int(n_total),
        "chunks_total": int(chunks_seen),
        "chunks_salvaged": int(salvaged_id + 1),
        "chunks_computed": int(computed),
        "coverage": float(done_stop / n_total) if n_total else 0.0,
        "max_chunk_points": int(max_chunk_points),
        "strategy": requested,
        "final_strategy": strat_now,
        "final_mesh": (list(mesh_shape_of(mesh_now))
                       if mesh_now is not None else None),
        "resumed": bool(salvage is not None),
        "truncated": bool(truncated),
        "recovery": recovery,
    }
    if salvage is not None:
        report["dropped_tail_records"] = int(salvage.dropped_tail)
        report["dropped_snapshots"] = int(salvage.dropped_snapshots)
    return ChunkedResult(grid=acc, report=report, journal_path=journal)
