"""Kernel (distance-decay) functions for STKDE.

The paper's inline formulas are typo'd versions of the product Epanechnikov
kernels used by the gold standard it cites ([HDTC16], [NY10]); we implement
the literature forms (DESIGN.md §1):

    ks(u, v) = 2/pi * (1 - (u^2 + v^2))^2        for u^2 + v^2 < 1, else 0
    kt(w)    = 3/4  * (1 - w^2)                  for |w| < 1,       else 0

Both are kept pluggable: every algorithm takes ``spatial_kernel`` /
``temporal_kernel`` callables so alternative kernels (paper-verbatim,
Gaussian-truncated, ...) can be swapped in. The structural property every
algorithm relies on is *separability*:
``contribution(X, Y, T) = Ks(X, Y) * Kt(T)``.
"""
from __future__ import annotations

from typing import Callable

import jax.numpy as jnp

Array = jnp.ndarray
SpatialKernel = Callable[[Array, Array], Array]
TemporalKernel = Callable[[Array], Array]


def ks_epanechnikov(u: Array, v: Array) -> Array:
    """2-D quartic (Epanechnikov-type) spatial kernel, zero outside unit disk."""
    r2 = u * u + v * v
    val = (2.0 / jnp.pi) * jnp.square(1.0 - r2)
    return jnp.where(r2 < 1.0, val, 0.0)


def kt_epanechnikov(w: Array) -> Array:
    """1-D Epanechnikov temporal kernel, zero outside |w| < 1."""
    val = 0.75 * (1.0 - w * w)
    return jnp.where(jnp.abs(w) < 1.0, val, 0.0)


def ks_paper_verbatim(u: Array, v: Array) -> Array:
    """The paper's inline formula, kept for completeness/ablation.

    ``pi/2 (1-u)^2 (1-v)^2`` with the support restricted (as the paper's
    summation condition says) to the unit disk.
    """
    r2 = u * u + v * v
    val = (jnp.pi / 2.0) * jnp.square(1.0 - u) * jnp.square(1.0 - v)
    return jnp.where(r2 < 1.0, val, 0.0)


def kt_paper_verbatim(w: Array) -> Array:
    val = 0.75 * jnp.square(1.0 - w)
    return jnp.where(jnp.abs(w) < 1.0, val, 0.0)


DEFAULT_KS: SpatialKernel = ks_epanechnikov
DEFAULT_KT: TemporalKernel = kt_epanechnikov


def normalization(n: int, hs: float, ht: float) -> float:
    """1 / (n hs^2 ht) — folded into Ks by the PB-SYM algorithms."""
    return 1.0 / (float(n) * hs * hs * ht)
