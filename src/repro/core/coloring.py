"""Stencil-graph coloring and critical-path machinery (paper §5.2).

On a shared-memory machine the paper turns subdomain dependencies (27-point
stencil) into a colored task DAG and schedules it with OpenMP tasks. SPMD
TPU execution has no dynamic task scheduler, so in this framework the
*placement* (``distributed/partition.py`` LPT) absorbs the load-balancing
role. This module keeps the paper's analysis machinery:

  * ``naive_coloring``     — the 8-color (2x2x2 parity) scheme of PB-SYM-PD
  * ``load_aware_coloring``— greedy, heaviest-subdomain-first (PB-SYM-PD-SCHED)
  * ``critical_path``      — T_inf of the implied DAG; with T_1 it gives
                             Graham's bound  T_P <= (T_1 - T_inf)/P + T_inf
  * ``simulate_schedule``  — list-scheduling simulation of the colored DAG on
                             P workers (reproduces the paper's Fig. 11-13
                             speedup story without OpenMP)
  * ``replicate_critical`` — PB-SYM-PD-REP's transformation: split tasks on
                             the critical path until T_inf <= T_1 / (2P)

All functions are host-side numpy (planning/analysis, not accelerator work).
"""
from __future__ import annotations

import heapq
from typing import Dict, List, Tuple

import numpy as np


Shape3 = Tuple[int, int, int]


def _neighbors(shape: Shape3):
    """Yield (flat_id, [flat neighbor ids]) for the 27-point stencil."""
    nx, ny, nz = shape
    strides = (ny * nz, nz, 1)

    def flat(i, j, k):
        return i * strides[0] + j * strides[1] + k

    for i in range(nx):
        for j in range(ny):
            for k in range(nz):
                nbrs = []
                for di in (-1, 0, 1):
                    for dj in (-1, 0, 1):
                        for dk in (-1, 0, 1):
                            if di == dj == dk == 0:
                                continue
                            a, b, c = i + di, j + dj, k + dk
                            if 0 <= a < nx and 0 <= b < ny and 0 <= c < nz:
                                nbrs.append(flat(a, b, c))
                yield flat(i, j, k), nbrs


def naive_coloring(shape: Shape3) -> np.ndarray:
    """8-color parity scheme: color = (i&1)<<2 | (j&1)<<1 | (k&1)."""
    nx, ny, nz = shape
    i, j, k = np.meshgrid(
        np.arange(nx), np.arange(ny), np.arange(nz), indexing="ij"
    )
    return ((i & 1) << 2 | (j & 1) << 1 | (k & 1)).reshape(-1)


def load_aware_coloring(shape: Shape3, loads: np.ndarray) -> np.ndarray:
    """Greedy coloring, vertices in non-increasing load order (PD-SCHED)."""
    loads = np.asarray(loads).reshape(-1)
    n = loads.size
    adj: Dict[int, List[int]] = dict(_neighbors(shape))
    order = np.argsort(-loads, kind="stable")
    colors = np.full(n, -1, dtype=np.int64)
    for v in order:
        used = {colors[u] for u in adj[v] if colors[u] >= 0}
        c = 0
        while c in used:
            c += 1
        colors[v] = c
    return colors


def _dag_edges(shape: Shape3, colors: np.ndarray):
    """Stencil edges oriented low color -> high color."""
    for v, nbrs in _neighbors(shape):
        for u in nbrs:
            if colors[u] < colors[v] or (colors[u] == colors[v] and u < v):
                yield u, v


def critical_path(shape: Shape3, colors: np.ndarray,
                  loads: np.ndarray) -> float:
    """T_inf: longest weighted chain of the color-oriented DAG."""
    loads = np.asarray(loads, dtype=np.float64).reshape(-1)
    n = loads.size
    # topological order: by (color, id) — valid since edges go low->high
    order = np.lexsort((np.arange(n), colors))
    cp = loads.copy()
    preds: Dict[int, List[int]] = {v: [] for v in range(n)}
    for u, v in _dag_edges(shape, colors):
        preds[v].append(u)
    for v in order:
        if preds[v]:
            cp[v] = loads[v] + max(cp[u] for u in preds[v])
    return float(cp.max()) if n else 0.0


def simulate_schedule(shape: Shape3, colors: np.ndarray, loads: np.ndarray,
                      P: int) -> float:
    """Greedy list-scheduling makespan of the colored DAG on P workers."""
    loads = np.asarray(loads, dtype=np.float64).reshape(-1)
    n = loads.size
    indeg = np.zeros(n, dtype=np.int64)
    succs: Dict[int, List[int]] = {v: [] for v in range(n)}
    for u, v in _dag_edges(shape, colors):
        succs[u].append(v)
        indeg[v] += 1
    # ready queue ordered by color then heaviest-first (the paper's policy)
    ready = [(colors[v], -loads[v], v) for v in range(n) if indeg[v] == 0]
    heapq.heapify(ready)
    workers = [0.0] * P  # next-free times
    finish = np.zeros(n, dtype=np.float64)
    release = {v: 0.0 for v in range(n) if indeg[v] == 0}
    done = 0
    while ready:
        _, _, v = heapq.heappop(ready)
        w = min(range(P), key=lambda i: workers[i])
        start = max(workers[w], release[v])
        finish[v] = start + loads[v]
        workers[w] = finish[v]
        done += 1
        for s in succs[v]:
            indeg[s] -= 1
            release[s] = max(release.get(s, 0.0), finish[v])
            if indeg[s] == 0:
                heapq.heappush(ready, (colors[s], -loads[s], s))
    assert done == n, "cycle in colored DAG"
    return float(finish.max()) if n else 0.0


def replicate_critical(shape: Shape3, colors: np.ndarray, loads: np.ndarray,
                       P: int, max_rounds: int = 64):
    """PB-SYM-PD-REP: split critical-path tasks until T_inf <= T_1 / (2P).

    Returns (effective_loads, replication) where ``replication[v]`` is the
    number of ways task v was split (its points are processed by that many
    workers; the merge cost is accounted as one extra unit of its shard).
    """
    loads = np.asarray(loads, dtype=np.float64).reshape(-1)
    T1 = loads.sum()
    rep = np.ones(loads.size, dtype=np.int64)
    eff = loads.copy()
    for _ in range(max_rounds):
        tinf = critical_path(shape, colors, eff)
        if tinf <= T1 / (2 * P) or tinf <= 0:
            break
        # find tasks on (near) the critical chain: greedy — heaviest first
        v = int(np.argmax(eff))
        rep[v] += 1
        eff[v] = loads[v] / rep[v] * (1.0 + 0.1)  # shard + merge overhead
    return eff, rep


def graham_bound(T1: float, Tinf: float, P: int) -> float:
    return (T1 - Tinf) / P + Tinf
