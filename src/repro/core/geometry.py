"""Domain / grid geometry for STKDE.

Conventions (DESIGN.md §6):
  * The domain is a box ``[ox, ox+gx) x [oy, oy+gy) x [ot, ot+gt)`` in
    *domain space* (meters / days).
  * Voxel ``(X, Y, T)`` samples the domain at its **center**
    ``origin + (idx + 0.5) * res``.
  * Uppercase = voxel space, lowercase = domain space (paper Table 1).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Tuple

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class Domain:
    """Discretized space-time domain.

    Attributes mirror the paper's notation: ``g*`` domain extent, ``sres`` /
    ``tres`` resolutions, ``G*`` grid extents in voxels, ``hs`` / ``ht``
    bandwidths (domain space), ``Hs`` / ``Ht`` bandwidths in voxels.
    """

    gx: float
    gy: float
    gt: float
    sres: float
    tres: float
    hs: float
    ht: float
    ox: float = 0.0
    oy: float = 0.0
    ot: float = 0.0

    # ------------------------------------------------------------------ grid
    @property
    def Gx(self) -> int:
        return max(1, math.ceil(self.gx / self.sres))

    @property
    def Gy(self) -> int:
        return max(1, math.ceil(self.gy / self.sres))

    @property
    def Gt(self) -> int:
        return max(1, math.ceil(self.gt / self.tres))

    @property
    def grid_shape(self) -> Tuple[int, int, int]:
        return (self.Gx, self.Gy, self.Gt)

    @property
    def Hs(self) -> int:
        return max(1, math.ceil(self.hs / self.sres))

    @property
    def Ht(self) -> int:
        return max(1, math.ceil(self.ht / self.tres))

    @property
    def grid_voxels(self) -> int:
        return self.Gx * self.Gy * self.Gt

    @property
    def grid_mbytes(self) -> float:
        return self.grid_voxels * 4 / 2**20

    @property
    def cylinder_voxels(self) -> int:
        """Voxels in one point's bounding box (2Hs+1)^2 x (2Ht+1)."""
        return (2 * self.Hs + 1) ** 2 * (2 * self.Ht + 1)

    # ------------------------------------------------------- transformations
    def voxel_centers_x(self) -> jnp.ndarray:
        return self.ox + (jnp.arange(self.Gx, dtype=jnp.float32) + 0.5) * self.sres

    def voxel_centers_y(self) -> jnp.ndarray:
        return self.oy + (jnp.arange(self.Gy, dtype=jnp.float32) + 0.5) * self.sres

    def voxel_centers_t(self) -> jnp.ndarray:
        return self.ot + (jnp.arange(self.Gt, dtype=jnp.float32) + 0.5) * self.tres

    def point_voxels(self, pts: jnp.ndarray) -> jnp.ndarray:
        """Map points ``(n, 3)`` [x, y, t] -> integer voxel indices ``(n, 3)``.

        Clipped into the grid so every point has a well-defined home voxel.
        """
        hi = jnp.asarray(
            [self.Gx - 1, self.Gy - 1, self.Gt - 1], dtype=jnp.int32
        )
        return jnp.clip(self.point_voxels_unclipped(pts), 0, hi)

    def point_voxels_unclipped(self, pts: jnp.ndarray) -> jnp.ndarray:
        """Voxel indices that may lie outside the grid (for subdomain views:
        a point outside a local domain still radiates density into it)."""
        scale = jnp.asarray([self.sres, self.sres, self.tres], dtype=pts.dtype)
        orig = jnp.asarray([self.ox, self.oy, self.ot], dtype=pts.dtype)
        return jnp.floor((pts - orig) / scale).astype(jnp.int32)

    def with_bandwidth(self, hs: float, ht: float) -> "Domain":
        return dataclasses.replace(self, hs=hs, ht=ht)

    def with_resolution(self, sres: float, tres: float) -> "Domain":
        return dataclasses.replace(self, sres=sres, tres=tres)

    # ------------------------------------------------------------- reporting
    def describe(self) -> str:
        return (
            f"grid {self.Gx}x{self.Gy}x{self.Gt} ({self.grid_mbytes:.0f} MB)"
            f" Hs={self.Hs} Ht={self.Ht} cyl={self.cylinder_voxels} vox"
        )


def from_points(
    pts: np.ndarray, sres: float, tres: float, hs: float, ht: float
) -> Domain:
    """Build a Domain whose box is the bounding box of ``pts`` (+1 voxel pad)."""
    pts = np.asarray(pts)
    lo = pts.min(axis=0)
    hi = pts.max(axis=0)
    span = np.maximum(hi - lo, [sres, sres, tres])
    return Domain(
        gx=float(span[0] + sres),
        gy=float(span[1] + sres),
        gt=float(span[2] + tres),
        sres=sres,
        tres=tres,
        hs=hs,
        ht=ht,
        ox=float(lo[0]),
        oy=float(lo[1]),
        ot=float(lo[2]),
    )
